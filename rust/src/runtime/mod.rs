//! PJRT runtime: load the AOT-compiled query executable (HLO text emitted
//! by `python/compile/aot.py`) and run it from the L3 hot path.
//!
//! Python never executes at runtime — `make artifacts` lowers the L2 JAX
//! model (wrapping the L1 Pallas kernel) to HLO text once; this module
//! parses it with `HloModuleProto::from_text_file`, compiles it on the
//! PJRT CPU client, and exposes a typed [`PerfDbExec`] the tuner calls.
//!
//! Two execution modes (compared in `benches/perfdb_query.rs` and logged
//! in EXPERIMENTS.md §Perf):
//! * literal mode — upload the (padded) database matrix with every call;
//! * cached-buffer mode (default) — the database lives in a device
//!   buffer created once at load time; per query only the 32-byte query
//!   vector is transferred (`execute_b`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::parse::ConfigDoc;
use crate::perfdb::native::NnQuery;
use crate::perfdb::{PerfDb, DIMS};

/// Sentinel coordinate for padding rows — must match
/// `python/compile/model.py::PAD_VALUE`.
pub const PAD_VALUE: f32 = 100.0;

/// Parsed `artifacts/manifest.txt`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub single: String,
    pub batched: String,
    pub topk: String,
    pub top_k: usize,
    pub n_records: usize,
    pub batch_q: usize,
    pub dims: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let doc: ConfigDoc = crate::config::parse::parse_file(&path)?;
        let m = Manifest {
            dir: dir.to_path_buf(),
            single: doc.get_str("artifacts", "single")?.to_string(),
            batched: doc.get_str("artifacts", "batched")?.to_string(),
            topk: doc.str_or("artifacts", "topk", "").to_string(),
            top_k: doc.i64_or("artifacts", "top_k", 1) as usize,
            n_records: doc.get_i64("artifacts", "n_records")? as usize,
            batch_q: doc.get_i64("artifacts", "batch_q")? as usize,
            dims: doc.get_i64("artifacts", "dims")? as usize,
        };
        anyhow::ensure!(m.dims == DIMS, "manifest dims {} != {}", m.dims, DIMS);
        Ok(m)
    }

    pub fn single_path(&self) -> PathBuf {
        self.dir.join(&self.single)
    }

    pub fn batched_path(&self) -> PathBuf {
        self.dir.join(&self.batched)
    }

    pub fn topk_path(&self) -> PathBuf {
        self.dir.join(&self.topk)
    }
}

/// The AOT query executable, compiled once, plus the uploaded database.
pub struct PerfDbExec {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    /// Padded, flattened (n_slots × DIMS) database matrix.
    db_flat: Vec<f32>,
    /// Device-resident database (cached-buffer mode).
    db_buffer: Option<xla::PjRtBuffer>,
    n_slots: usize,
    real_records: usize,
    /// Queries per invocation this executable was lowered for.
    pub n_q: usize,
    /// Neighbours returned per query (1 for argmin, k for top-k).
    pub out_k: usize,
}

impl PerfDbExec {
    /// Compile `hlo_path` (lowered for `n_q` queries × `n_slots` records)
    /// and upload `db`'s normalized vectors (padded to `n_slots`).
    pub fn load(hlo_path: &Path, db: &PerfDb, n_q: usize, n_slots: usize) -> Result<Self> {
        Self::load_k(hlo_path, db, n_q, n_slots, 1)
    }

    /// As [`Self::load`] for an executable returning `out_k` neighbours.
    pub fn load_k(
        hlo_path: &Path,
        db: &PerfDb,
        n_q: usize,
        n_slots: usize,
        out_k: usize,
    ) -> Result<Self> {
        anyhow::ensure!(
            db.len() <= n_slots,
            "database has {} records but the artifact was lowered for {n_slots}; \
             regenerate with `python -m compile.aot --n-records <bigger>`",
            db.len()
        );
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).context("PJRT compile")?;

        let mut db_flat = vec![PAD_VALUE; n_slots * DIMS];
        for (i, r) in db.records.iter().enumerate() {
            db_flat[i * DIMS..(i + 1) * DIMS].copy_from_slice(&r.vec);
        }
        let db_buffer = client
            .buffer_from_host_buffer(&db_flat, &[n_slots, DIMS], None)
            .context("uploading database buffer")?;
        Ok(PerfDbExec {
            exe,
            client,
            db_flat,
            db_buffer: Some(db_buffer),
            n_slots,
            real_records: db.len(),
            n_q,
            out_k,
        })
    }

    /// Disable the cached device buffer (literal mode — the §Perf
    /// baseline: re-uploads the database on every query).
    pub fn set_cached(&mut self, cached: bool) {
        if cached && self.db_buffer.is_none() {
            self.db_buffer = self
                .client
                .buffer_from_host_buffer(&self.db_flat, &[self.n_slots, DIMS], None)
                .ok();
        } else if !cached {
            self.db_buffer = None;
        }
    }

    pub fn cached(&self) -> bool {
        self.db_buffer.is_some()
    }

    /// Run one batch of queries (length must equal `n_q`). Returns
    /// (record index, squared distance) per query.
    pub fn query_batch(&self, qs: &[[f32; DIMS]]) -> Result<Vec<(usize, f32)>> {
        anyhow::ensure!(
            qs.len() == self.n_q,
            "executable lowered for {} queries, got {}",
            self.n_q,
            qs.len()
        );
        let q_flat: Vec<f32> = qs.iter().flatten().copied().collect();
        let result = match &self.db_buffer {
            Some(db_buf) => {
                let q_buf = self
                    .client
                    .buffer_from_host_buffer(&q_flat, &[self.n_q, DIMS], None)?;
                self.exe.execute_b(&[&q_buf, db_buf])?
            }
            None => {
                let q_lit = xla::Literal::vec1(&q_flat).reshape(&[self.n_q as i64, DIMS as i64])?;
                let db_lit = xla::Literal::vec1(&self.db_flat)
                    .reshape(&[self.n_slots as i64, DIMS as i64])?;
                self.exe.execute::<xla::Literal>(&[q_lit, db_lit])?
            }
        };
        let out = result[0][0].to_literal_sync()?;
        let (idx_lit, dist_lit) = out.to_tuple2()?;
        let idxs = idx_lit.to_vec::<i32>()?;
        let dists = dist_lit.to_vec::<f32>()?;
        let want = self.n_q * self.out_k;
        anyhow::ensure!(idxs.len() == want && dists.len() == want, "bad output arity");
        let mut res = Vec::with_capacity(want);
        for (i, (&idx, &d)) in idxs.iter().zip(&dists).enumerate() {
            let idx = idx as usize;
            // top-k tails may reach padding rows when k > real records;
            // the caller filters those out.
            if self.out_k == 1 {
                anyhow::ensure!(
                    idx < self.real_records,
                    "query {i}: nearest slot {idx} is a padding row ({} real records)",
                    self.real_records
                );
            }
            res.push((idx, d));
        }
        Ok(res)
    }

    pub fn real_records(&self) -> usize {
        self.real_records
    }

    /// Single-query convenience (for `n_q == 1` executables).
    pub fn query(&self, q: &[f32; DIMS]) -> Result<(usize, f32)> {
        Ok(self.query_batch(std::slice::from_ref(q))?[0])
    }
}

/// [`NnQuery`] backend over the AOT executable — what the tuner uses in
/// production mode. Carries both the argmin and (when the artifact
/// exists) the on-device top-k executables.
pub struct XlaNn {
    exec: PerfDbExec,
    topk_exec: Option<PerfDbExec>,
}

impl XlaNn {
    /// Load from the artifact manifest directory (default `artifacts/`).
    pub fn from_manifest(dir: &Path, db: &PerfDb) -> Result<Self> {
        let m = Manifest::load(dir)?;
        let exec = PerfDbExec::load(&m.single_path(), db, 1, m.n_records)?;
        let topk_exec = if !m.topk.is_empty() && m.topk_path().exists() {
            Some(PerfDbExec::load_k(&m.topk_path(), db, 1, m.n_records, m.top_k)?)
        } else {
            None
        };
        Ok(XlaNn { exec, topk_exec })
    }

    pub fn exec(&self) -> &PerfDbExec {
        &self.exec
    }

    pub fn exec_mut(&mut self) -> &mut PerfDbExec {
        &mut self.exec
    }
}

/// Both query backends must be able to move onto a
/// [`crate::service::TunerService`] aggregation thread, where one
/// instance serves every session — keep them `Send` or this stops
/// compiling.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<XlaNn>();
    assert_send::<PerfDbExec>();
    assert_send::<crate::perfdb::native::NativeNn>();
};

/// The query backend a tuner service should share across sessions: the
/// AOT XLA executable when the artifact manifest loads, else the native
/// brute-force oracle. Returns the boxed backend plus its name for
/// logs — how `tuna serve` stands up its [`crate::service::TunerService`]
/// (`tuna tune` keeps its explicit `--xla` opt-in, which errors instead
/// of falling back).
pub fn service_backend(
    artifacts: &Path,
    db: &PerfDb,
) -> (Box<dyn NnQuery + Send>, &'static str) {
    match XlaNn::from_manifest(artifacts, db) {
        Ok(x) => (Box::new(x), "xla"),
        Err(_) => (Box::new(crate::perfdb::native::NativeNn::new(db)), "native"),
    }
}

/// Shared "AOT artifacts missing" gate: `true` when `dir` lacks the
/// manifest, in which case the caller should skip whatever needed the
/// XLA executables. The historical stderr line is preserved verbatim
/// (CI and humans grep for it) and the skip also lands as a structured
/// warn event on `obs` when a recorder is enabled, so skipped coverage
/// shows up in journals, not just scrolled-past terminal output.
pub fn skip_without_artifacts(dir: &Path, obs: &crate::obs::Recorder) -> bool {
    if dir.join("manifest.txt").exists() {
        return false;
    }
    eprintln!("skipping: run `make artifacts` first");
    obs.warn_event("runtime::artifacts", "skipping: run `make artifacts` first");
    true
}

impl NnQuery for XlaNn {
    fn nearest(&mut self, q: &[f32; DIMS]) -> Result<(usize, f32)> {
        self.exec.query(q)
    }

    fn top_k(&mut self, q: &[f32; DIMS], k: usize) -> Result<Vec<(usize, f32)>> {
        match &self.topk_exec {
            Some(exec) => {
                let all = exec.query_batch(std::slice::from_ref(q))?;
                Ok(all
                    .into_iter()
                    .filter(|&(idx, _)| idx < exec.real_records())
                    .take(k)
                    .collect())
            }
            None => Ok(vec![self.exec.query(q)?]),
        }
    }

    fn backend(&self) -> &'static str {
        "xla"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::native::{dist2, NativeNn};
    use crate::perfdb::{normalize, Record};
    use crate::util::rng::Rng;

    fn artifacts_dir() -> PathBuf {
        // tests run from the crate root
        PathBuf::from("artifacts")
    }

    /// Every artifact-dependent test shares one gate (the module-level
    /// [`super::skip_without_artifacts`]); tests carry no recorder, so
    /// the structured half is a no-op here and only the verbatim stderr
    /// line survives — exactly the historical behavior.
    fn skip_without_artifacts() -> bool {
        super::skip_without_artifacts(&artifacts_dir(), &crate::obs::Recorder::disabled())
    }

    fn random_db(n: usize, seed: u64) -> PerfDb {
        let mut rng = Rng::new(seed);
        let records = (0..n)
            .map(|_| {
                let raw = [
                    rng.range_f64(100.0, 200_000.0),
                    rng.range_f64(0.0, 50_000.0),
                    rng.range_f64(0.0, 400.0),
                    rng.range_f64(0.0, 400.0),
                    rng.range_f64(0.01, 20.0),
                    rng.range_f64(3_000.0, 40_000.0),
                    2.0,
                    16.0,
                ];
                Record { raw, vec: normalize(&raw), times_ns: vec![1.0] }
            })
            .collect();
        PerfDb { fractions: vec![1.0], records }
    }

    #[test]
    fn manifest_parses() {
        if skip_without_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        assert_eq!(m.dims, DIMS);
        assert!(m.n_records >= 1024);
        assert!(m.single_path().exists());
        assert!(m.batched_path().exists());
    }

    #[test]
    fn xla_query_matches_native_oracle() {
        if skip_without_artifacts() {
            return;
        }
        let db = random_db(1000, 7);
        let mut xla_nn = XlaNn::from_manifest(&artifacts_dir(), &db).unwrap();
        let mut native = NativeNn::new(&db);
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let raw = [
                rng.range_f64(100.0, 200_000.0),
                rng.range_f64(0.0, 50_000.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.01, 20.0),
                rng.range_f64(3_000.0, 40_000.0),
                2.0,
                16.0,
            ];
            let q = normalize(&raw);
            let (xi, xd) = xla_nn.nearest(&q).unwrap();
            let (ni, nd) = native.nearest(&q).unwrap();
            // indices can differ on exact ties; distances must agree
            assert!(
                (xd - nd).abs() < 1e-5,
                "dist mismatch: xla {xd} native {nd}"
            );
            if (dist2(&q, &db.records[xi].vec) - dist2(&q, &db.records[ni].vec)).abs() > 1e-5 {
                panic!("xla idx {xi} is not a true nearest (native {ni})");
            }
        }
    }

    #[test]
    fn exact_record_match_roundtrip() {
        if skip_without_artifacts() {
            return;
        }
        let db = random_db(500, 3);
        let xla_nn = XlaNn::from_manifest(&artifacts_dir(), &db).unwrap();
        let (idx, dist) = xla_nn.exec().query(&db.records[123].vec).unwrap();
        assert_eq!(idx, 123);
        assert!(dist.abs() < 1e-5, "dist={dist}");
    }

    #[test]
    fn literal_mode_matches_cached_mode() {
        if skip_without_artifacts() {
            return;
        }
        let db = random_db(700, 11);
        let mut xla_nn = XlaNn::from_manifest(&artifacts_dir(), &db).unwrap();
        let q = db.records[42].vec;
        let cached = xla_nn.exec().query(&q).unwrap();
        xla_nn.exec_mut().set_cached(false);
        assert!(!xla_nn.exec().cached());
        let literal = xla_nn.exec().query(&q).unwrap();
        assert_eq!(cached.0, literal.0);
        assert!((cached.1 - literal.1).abs() < 1e-6);
    }

    #[test]
    fn xla_topk_matches_native_topk() {
        if skip_without_artifacts() {
            return;
        }
        let db = random_db(900, 21);
        let mut xla_nn = XlaNn::from_manifest(&artifacts_dir(), &db).unwrap();
        let native = NativeNn::new(&db);
        let mut rng = Rng::new(5);
        for _ in 0..10 {
            let raw = [
                rng.range_f64(100.0, 200_000.0),
                rng.range_f64(0.0, 50_000.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.0, 400.0),
                rng.range_f64(0.01, 20.0),
                rng.range_f64(3_000.0, 40_000.0),
                2.0,
                16.0,
            ];
            let q = normalize(&raw);
            let xt = crate::perfdb::native::NnQuery::top_k(&mut xla_nn, &q, 4).unwrap();
            let nt = native.top_k(&q, 4);
            assert_eq!(xt.len(), 4);
            for (a, b) in xt.iter().zip(&nt) {
                assert!((a.1 - b.1).abs() < 1e-4, "xla {a:?} native {b:?}");
            }
        }
    }

    #[test]
    fn oversized_db_is_rejected() {
        if skip_without_artifacts() {
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let db = random_db(m.n_records + 1, 5);
        assert!(PerfDbExec::load(&m.single_path(), &db, 1, m.n_records).is_err());
    }
}
