//! Small self-contained utilities.
//!
//! The build image has no access to the crates.io registry (the `xla` and
//! `anyhow` dependencies are vendored under `rust/vendor/`), so the usual
//! suspects (`rand`, `proptest`, `serde`, `clap`, `criterion`) are
//! hand-rolled here at the scale this project needs. See DESIGN.md §2
//! (crate substitutions).

pub mod bench;
pub mod parallel;
pub mod proptest;
pub mod rng;
pub mod stats;

/// Format a byte count as a human-readable string (binary units).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = bytes as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format a nanosecond count as a human-readable duration.
pub fn human_ns(ns: u64) -> String {
    if ns < 1_000 {
        format!("{ns} ns")
    } else if ns < 1_000_000 {
        format!("{:.2} us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2} ms", ns as f64 / 1e6)
    } else {
        format!("{:.3} s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(17), "17 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
        assert_eq!(human_bytes(5 * 1024 * 1024 * 1024), "5.0 GiB");
    }

    #[test]
    fn human_ns_units() {
        assert_eq!(human_ns(900), "900 ns");
        assert_eq!(human_ns(1500), "1.50 us");
        assert_eq!(human_ns(2_500_000), "2.50 ms");
        assert_eq!(human_ns(1_250_000_000), "1.250 s");
    }
}
