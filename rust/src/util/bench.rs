//! Tiny benchmarking harness (criterion is unavailable offline).
//!
//! Provides wall-clock timing with warmup, repetition, and simple
//! statistics, plus helpers the `[[bench]] harness = false` targets use to
//! print paper-style tables.

use std::time::Instant;

use super::stats::percentile;

/// Result of timing a closure repeatedly.
#[derive(Clone, Debug)]
pub struct Timing {
    /// Per-iteration wall times in nanoseconds, sorted ascending.
    pub samples_ns: Vec<f64>,
}

impl Timing {
    pub fn mean_ns(&self) -> f64 {
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn p50_ns(&self) -> f64 {
        percentile(&self.samples_ns, 0.5)
    }

    pub fn p95_ns(&self) -> f64 {
        percentile(&self.samples_ns, 0.95)
    }

    pub fn min_ns(&self) -> f64 {
        self.samples_ns[0]
    }
}

/// Time `f` with `warmup` unmeasured runs then `iters` measured runs.
/// Returns per-iteration statistics. `f` should return something observable
/// (use [`std::hint::black_box`] inside) to prevent dead-code elimination.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Timing { samples_ns: samples }
}

/// Time a single run of `f` in nanoseconds.
pub fn time_once<F: FnOnce()>(f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_nanos() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_collects_samples() {
        let t = time_it(2, 10, || {
            std::hint::black_box((0..100u64).sum::<u64>());
        });
        assert_eq!(t.samples_ns.len(), 10);
        assert!(t.min_ns() >= 0.0);
        assert!(t.p95_ns() >= t.p50_ns());
        assert!(t.mean_ns() > 0.0);
    }
}
