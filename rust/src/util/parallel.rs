//! Scoped-thread worker pool (rayon is unavailable offline).
//!
//! One shared implementation of the index-pulling pool used by the sweep
//! executor and the perf-DB builder: workers pull the next index from an
//! atomic counter, results are collected as `(index, value)` pairs and
//! restored to index order before returning — so scheduling can never
//! reorder or drop outputs, and callers get deterministic results for any
//! thread count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Evaluate `f(0)..f(n-1)` on up to `threads` scoped worker threads and
/// return the results in index order. `threads` is clamped to `[1, n]`;
/// pass the result of [`default_threads`] (or 0 handled by the caller)
/// for "one per core".
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let next = AtomicUsize::new(0);
    let slots: Mutex<Vec<(usize, T)>> = Mutex::new(Vec::with_capacity(n));
    std::thread::scope(|scope| {
        for _ in 0..threads.clamp(1, n) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f(i);
                slots.lock().unwrap().push((i, r));
            });
        }
    });
    let mut v = slots.into_inner().unwrap();
    v.sort_by_key(|slot| slot.0);
    v.into_iter().map(|(_, r)| r).collect()
}

/// One worker per available core (fallback 4).
pub fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order_for_any_thread_count() {
        for threads in [1, 2, 8, 64] {
            let out = parallel_map(100, threads, |i| i * 3);
            assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn empty_input_and_oversized_pool() {
        assert!(parallel_map(0, 8, |i| i).is_empty());
        assert_eq!(parallel_map(3, 100, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn non_copy_results_are_collected() {
        let out = parallel_map(10, 4, |i| format!("v{i}"));
        assert_eq!(out[7], "v7");
        assert_eq!(out.len(), 10);
    }
}
