//! Streaming statistics helpers used by telemetry, benches and reports.

/// Online mean/variance/min/max accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { f64::NAN } else { self.mean }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample (linear interpolation, `q` in `[0, 1]`).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Linear interpolation of `y(x)` over sorted sample points `(xs, ys)`.
/// Clamps outside the range. Used to read execution-time records at FM
/// sizes between the database's sampled grid points.
pub fn lerp_at(xs: &[f64], ys: &[f64], x: f64) -> f64 {
    assert_eq!(xs.len(), ys.len());
    assert!(!xs.is_empty());
    if x <= xs[0] {
        return ys[0];
    }
    if x >= xs[xs.len() - 1] {
        return ys[ys.len() - 1];
    }
    // xs sorted ascending
    let mut i = 1;
    while xs[i] < x {
        i += 1;
    }
    let t = (x - xs[i - 1]) / (xs[i] - xs[i - 1]);
    ys[i - 1] * (1.0 - t) + ys[i] * t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.var() - 5.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    #[test]
    fn percentile_interp() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert!((percentile(&v, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lerp_clamps_and_interpolates() {
        let xs = [0.0, 1.0, 2.0];
        let ys = [10.0, 20.0, 40.0];
        assert_eq!(lerp_at(&xs, &ys, -1.0), 10.0);
        assert_eq!(lerp_at(&xs, &ys, 3.0), 40.0);
        assert!((lerp_at(&xs, &ys, 0.5) - 15.0).abs() < 1e-12);
        assert!((lerp_at(&xs, &ys, 1.5) - 30.0).abs() < 1e-12);
    }
}
