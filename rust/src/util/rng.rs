//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through `splitmix64`, plus the small set of
//! distributions the simulator and workloads need (uniform ranges, zipf,
//! shuffle, exponential). All simulation is seed-reproducible; every public
//! entry point threads an explicit seed so experiment tables are stable
//! across runs.

/// splitmix64 step — used for seeding and cheap hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent stream (for sub-components) without
    /// perturbing this generator's sequence.
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)` (f64).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed value with the given mean.
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (k << n assumed).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        while out.len() < k {
            let i = self.index(n);
            if seen.insert(i) {
                out.push(i);
            }
        }
        out
    }
}

/// Zipf-distributed sampler over `[0, n)` with skew `s`:
/// `P(k) ∝ 1/(k+1)^s`, rank 0 hottest.
///
/// Exact inverse-CDF sampling over a precomputed cumulative table
/// (O(n) setup once per distribution — graph builders construct a handful
/// of these — then O(log n) per sample, branch-predictable and allocation
/// free).
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// `n` elements with exponent `s > 0` (`s == 1` fine).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0 && s > 0.0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 0..n {
            acc += ((k + 1) as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Zipf { cdf }
    }

    /// Sample a rank in `[0, n)` (rank 0 is the hottest element).
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(9);
        let idx = rng.sample_indices(1000, 50);
        assert_eq!(idx.len(), 50);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 50);
        assert!(idx.iter().all(|&i| i < 1000));
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let z = Zipf::new(1000, 0.99);
        let mut rng = Rng::new(11);
        let mut counts = vec![0u32; 1000];
        for _ in 0..50_000 {
            let k = z.sample(&mut rng);
            assert!(k < 1000);
            counts[k] += 1;
        }
        // rank 0 should be sampled much more than rank 500
        assert!(counts[0] > 20 * counts[500].max(1));
        // head (top 10%) should dominate
        let head: u32 = counts[..100].iter().sum();
        assert!(head as f64 > 0.5 * 50_000.0, "head={head}");
    }

    #[test]
    fn exp_mean_close() {
        let mut rng = Rng::new(5);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.exp(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn fork_streams_diverge() {
        let mut r = Rng::new(123);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
