//! Equations (1)–(4) of §3.2: how many pages the micro-benchmark places in
//! each tier so that, within one profiling interval, it reproduces the
//! target page-access counts *excluding* the accesses that page migration
//! itself contributes.
//!
//! ```text
//! pacc_fast' = pacc_fast − pm_de × 1          (1)  demoted pages are
//!                                                  accessed once in fast
//! pacc_slow' = pacc_slow − pm_pr × hot_thr    (2)  promoted pages are
//!                                                  accessed hot_thr× in slow
//! NP_fast = pacc_fast' / hot_thr              (3)
//! NP_slow = pacc_slow' / (hot_thr − 1)        (4)
//! ```
//!
//! (The paper states the NP pages are accessed `hot_thr − 1` times each,
//! which keeps resident-set pages *below* the promotion threshold; we
//! follow that, noting the divisor of Eq. 3 counts one extra access that
//! TPP's NUMA-hint sampling consumes on fast-tier pages.)

/// Resolved page-set sizes for one micro-benchmark instantiation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PageSets {
    /// Resident pages in fast memory, each accessed `hot_thr − 1`/interval.
    pub np_fast: u64,
    /// Resident pages in slow memory, each accessed `hot_thr − 1`/interval.
    pub np_slow: u64,
    /// Pages promoted per interval (each accessed `hot_thr` times in slow).
    pub pm_pr: u64,
    /// Pages demoted per interval (each accessed once in fast, then cold).
    pub pm_de: u64,
}

/// Apply equations (1)–(4). Saturating: configurations where migration
/// accesses exceed the measured accesses clamp to zero (they arise from
/// noisy telemetry windows).
pub fn page_sets(pacc_f: u64, pacc_s: u64, pm_de: u64, pm_pr: u64, hot_thr: u32) -> PageSets {
    let hot_thr = hot_thr.max(1) as u64;
    let adj_f = pacc_f.saturating_sub(pm_de); // (1)
    let adj_s = pacc_s.saturating_sub(pm_pr * hot_thr); // (2)
    let np_fast = adj_f / hot_thr; // (3)
    let np_slow = if hot_thr > 1 { adj_s / (hot_thr - 1) } else { 0 }; // (4)
    PageSets { np_fast, np_slow, pm_pr, pm_de }
}

impl PageSets {
    /// Total resident pages the workload needs (excluding churn slack).
    pub fn resident_pages(&self) -> u64 {
        self.np_fast + self.np_slow
    }

    /// Fast/slow page accesses this instantiation performs per interval
    /// (the inverse of equations (1)–(4): used by tests to check
    /// round-trip consistency and by the DB to label records).
    pub fn accesses_per_interval(&self, hot_thr: u32) -> (u64, u64) {
        let h = hot_thr.max(1) as u64;
        let fast = self.np_fast * h + self.pm_de;
        let slow = if h > 1 { self.np_slow * (h - 1) } else { 0 } + self.pm_pr * h;
        (fast, slow)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_equations_hold() {
        let ps = page_sets(10_000, 5_000, 200, 300, 2);
        assert_eq!(ps.np_fast, (10_000 - 200) / 2);
        assert_eq!(ps.np_slow, 5_000 - 300 * 2); // /(2-1)
        assert_eq!(ps.pm_pr, 300);
        assert_eq!(ps.pm_de, 200);
    }

    #[test]
    fn roundtrip_recovers_pacc() {
        for hot_thr in [2u32, 3, 4, 8] {
            let (pf, ps_, de, pr) = (40_000u64, 9_000u64, 120u64, 250u64);
            let sets = page_sets(pf, ps_, de, pr, hot_thr);
            let (f, s) = sets.accesses_per_interval(hot_thr);
            // round-trip is exact up to the integer division remainder
            let h = hot_thr as u64;
            assert!(f <= pf && pf - f < h, "fast {f} vs {pf}");
            assert!(s <= ps_ && ps_ - s < (h - 1).max(1), "slow {s} vs {ps_}");
        }
    }

    #[test]
    fn hot_thr_one_puts_all_slow_traffic_on_promotions() {
        let ps = page_sets(1_000, 500, 0, 50, 1);
        assert_eq!(ps.np_slow, 0);
        let (_, s) = ps.accesses_per_interval(1);
        assert_eq!(s, 50);
    }

    #[test]
    fn saturation_on_noisy_windows() {
        let ps = page_sets(10, 10, 100, 100, 2);
        assert_eq!(ps.np_fast, 0);
        assert_eq!(ps.np_slow, 0);
    }
}
