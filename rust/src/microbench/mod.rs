//! The §3.2 micro-benchmark: a parameterized workload that emulates an
//! application *from the point of view of page migration*.
//!
//! Given the eight-element configuration vector
//! `[pacc_f, pacc_s, pm_de, pm_pr, AI, RSS, hot_thr, num_threads]`
//! the template instantiates a strided-access workload that, per profiling
//! interval, performs the same number of fast/slow page accesses, induces
//! the same number of promotions/demotions under the page-management
//! policy, and executes ops to match the arithmetic intensity. Accesses
//! are spread evenly over the page sets (maximum memory-level parallelism)
//! — the paper's stated "Limitation": the micro-benchmark models the
//! *best-case* memory performance.

pub mod equations;

pub use equations::{page_sets, PageSets};

use crate::workloads::{AccessProfile, PageAccess, Workload};
use crate::LINE_BYTES;

/// The eight-element configuration vector (§3.3), raw (unnormalized).
/// Count fields are per profiling interval.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MicrobenchConfig {
    /// Page accesses served by fast memory per interval.
    pub pacc_f: f64,
    /// Page accesses served by slow memory per interval.
    pub pacc_s: f64,
    /// Page demotions per interval.
    pub pm_de: f64,
    /// Page promotions per interval.
    pub pm_pr: f64,
    /// Arithmetic intensity, ops per byte accessed.
    pub ai: f64,
    /// Resident set size in pages.
    pub rss_pages: f64,
    /// Page-management promotion threshold.
    pub hot_thr: f64,
    /// Worker threads.
    pub num_threads: f64,
}

impl MicrobenchConfig {
    pub fn as_array(&self) -> [f64; 8] {
        [
            self.pacc_f,
            self.pacc_s,
            self.pm_de,
            self.pm_pr,
            self.ai,
            self.rss_pages,
            self.hot_thr,
            self.num_threads,
        ]
    }

    pub fn from_array(a: [f64; 8]) -> Self {
        MicrobenchConfig {
            pacc_f: a[0],
            pacc_s: a[1],
            pm_de: a[2],
            pm_pr: a[3],
            ai: a[4],
            rss_pages: a[5],
            hot_thr: a[6],
            num_threads: a[7],
        }
    }
}

/// The instantiated micro-benchmark workload.
///
/// Address-space layout (single flat array, matching the paper's two
/// strided arrays once placement happens fast-first):
///
/// ```text
/// [0, np_fast)                       resident fast set
/// [np_fast, np_fast+np_slow)         resident slow set
/// [resident, rss)                    churn pool: pm_pr pages heated to
///                                    hot_thr each interval (promoted),
///                                    previously-promoted pages go cold
///                                    (demoted by kswapd) — this is how
///                                    the pm_de/pm_pr targets are induced
///                                    under a real policy rather than
///                                    scripted.
/// ```
pub struct Microbench {
    cfg: MicrobenchConfig,
    sets: PageSets,
    rss: usize,
    churn_base: u64,
    churn_len: u64,
    churn_cursor: u64,
    intervals_left: u32,
    first_interval: bool,
    threads: u32,
}

impl Microbench {
    pub fn new(cfg: MicrobenchConfig, intervals: u32) -> Self {
        let hot_thr = cfg.hot_thr.max(1.0) as u32;
        let mut sets = page_sets(
            cfg.pacc_f.max(0.0) as u64,
            cfg.pacc_s.max(0.0) as u64,
            cfg.pm_de.max(0.0) as u64,
            cfg.pm_pr.max(0.0) as u64,
            hot_thr,
        );
        // Sanity clamp against garbage telemetry: cap the instantiated
        // address space at 2²¹ pages (8 GiB of 4 KiB pages — far above any
        // real configuration at our scale), scaling the page sets
        // proportionally so the fast/slow mix is preserved.
        const MAX_RESIDENT: u64 = 1 << 21;
        if sets.resident_pages() > MAX_RESIDENT {
            let scale = MAX_RESIDENT as f64 / sets.resident_pages() as f64;
            sets.np_fast = (sets.np_fast as f64 * scale) as u64;
            sets.np_slow = (sets.np_slow as f64 * scale) as u64;
        }
        sets.pm_pr = sets.pm_pr.min(MAX_RESIDENT / 8);
        sets.pm_de = sets.pm_de.min(MAX_RESIDENT / 8);
        // RSS must hold the resident sets plus a churn pool; grow it if
        // the configuration under-specifies (telemetry noise).
        let min_rss = sets.resident_pages() + 4 * sets.pm_pr.max(sets.pm_de) + 64;
        let rss = (cfg.rss_pages.max(0.0) as u64).max(min_rss) as usize;
        let churn_base = sets.resident_pages();
        let churn_len = rss as u64 - churn_base;
        Microbench {
            cfg,
            sets,
            rss,
            churn_base,
            churn_len,
            churn_cursor: 0,
            intervals_left: intervals,
            first_interval: true,
            threads: cfg.num_threads.max(1.0) as u32,
        }
    }

    pub fn config(&self) -> &MicrobenchConfig {
        &self.cfg
    }

    pub fn page_sets(&self) -> &PageSets {
        &self.sets
    }

    fn hot_thr(&self) -> u32 {
        self.cfg.hot_thr.max(1.0) as u32
    }
}

impl Workload for Microbench {
    fn name(&self) -> &'static str {
        "microbench"
    }

    fn rss_pages(&self) -> usize {
        self.rss
    }

    fn threads(&self) -> u32 {
        self.threads
    }

    fn next_interval(&mut self) -> Option<AccessProfile> {
        if self.intervals_left == 0 {
            return None;
        }
        self.intervals_left -= 1;

        if self.first_interval {
            // Initialization phase: touch every page once so the whole
            // RSS is physically allocated (fast-first, spilling to slow —
            // which lands the low-id resident-fast set in fast memory).
            self.first_interval = false;
            let accesses: Vec<PageAccess> = (0..self.rss as u32)
                .map(|p| PageAccess { page: p, random: 1, streamed: 0 })
                .collect();
            return Some(AccessProfile { accesses, flops: 0, iops: self.rss as u64 * 8 });
        }

        let h = self.hot_thr();
        // Strided accesses with maximum spread: the paper's design point.
        // Strides are predictable, so the hardware prefetchers cover about
        // a quarter of them (`streamed`); the rest are latency-exposed
        // (`random`). Together with the even spread this is the
        // best-case-MLP bias the paper's Limitation paragraph describes.
        let mut accesses: Vec<PageAccess> = Vec::with_capacity(
            (self.sets.np_fast + self.sets.np_slow + self.sets.pm_pr + self.sets.pm_de)
                as usize,
        );

        // resident fast set: evenly strided, hot_thr accesses per page
        // (Eq. 3's divisor — promotion is moot for fast-resident pages)
        for p in 0..self.sets.np_fast {
            // alternate fully-random and half-streamed pages → ~75% of
            // strided accesses latency-exposed, ~25% prefetch-covered
            let (r, st) = if p % 2 == 0 { (h, 0) } else { (h.div_ceil(2), h / 2) };
            accesses.push(PageAccess { page: p as u32, random: r, streamed: st });
        }
        // resident slow set: hot_thr − 1 accesses per page, staying just
        // below the promotion threshold (Eq. 4's divisor)
        for p in self.sets.np_fast..self.sets.np_fast + self.sets.np_slow {
            let c = (h - 1).max(1);
            let (r, st) = if p % 2 == 0 { (c, 0) } else { (c.div_ceil(2), c / 2) };
            accesses.push(PageAccess { page: p as u32, random: r, streamed: st });
        }
        // churn pool: heat pm_pr pages to exactly hot_thr (promotion
        // triggers), advancing a rotating cursor; pages promoted in
        // earlier intervals are no longer touched → they cool down and
        // become kswapd's demotion victims (inducing pm_de).
        for _ in 0..self.sets.pm_pr {
            let p = self.churn_base + (self.churn_cursor % self.churn_len);
            self.churn_cursor += 1;
            accesses.push(PageAccess { page: p as u32, random: h, streamed: 0 });
        }
        // demotion feed: touch pm_de fast-resident pages once (they are
        // "accessed once and then demoted" per §3.2) — reuse the oldest
        // churn pages which by now live in fast memory.
        for i in 0..self.sets.pm_de {
            let back = self.churn_cursor + self.churn_len - self.sets.pm_pr - i - 1;
            let p = self.churn_base + (back % self.churn_len);
            accesses.push(PageAccess { page: p as u32, random: 1, streamed: 0 });
        }

        let total: u64 = accesses.iter().map(|a| a.total() as u64).sum();
        let ops = (self.cfg.ai.max(0.0) * (total * LINE_BYTES) as f64) as u64;
        Some(AccessProfile { accesses, flops: ops / 2, iops: ops - ops / 2 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{Engine, IntervalModel, MachineModel};
    use crate::tpp::{Tpp, Watermarks};

    fn cfg() -> MicrobenchConfig {
        MicrobenchConfig {
            pacc_f: 8_000.0,
            pacc_s: 1_500.0,
            pm_de: 60.0,
            pm_pr: 60.0,
            ai: 0.5,
            rss_pages: 8_000.0,
            hot_thr: 2.0,
            num_threads: 16.0,
        }
    }

    #[test]
    fn config_array_roundtrip() {
        let c = cfg();
        assert_eq!(MicrobenchConfig::from_array(c.as_array()), c);
    }

    #[test]
    fn interval_access_counts_respect_pacc_targets() {
        let mut mb = Microbench::new(cfg(), 4);
        let _alloc = mb.next_interval().unwrap();
        let p = mb.next_interval().unwrap();
        let (want_f, want_s) = mb.page_sets().accesses_per_interval(2);
        let total = p.total_accesses();
        assert_eq!(total, want_f + want_s, "total accesses match equations");
        // AI respected: ops / bytes == cfg.ai
        let ai = p.arithmetic_intensity();
        assert!((ai - 0.5).abs() < 0.01, "ai={ai}");
    }

    #[test]
    fn induces_promotions_and_demotions_under_tpp() {
        let c = cfg();
        let mut mb = Microbench::new(c, 30);
        // fast memory sized so the resident-fast set fits but the slow
        // set does not: ~80% of RSS
        let cap = Engine::fm_capacity(mb.rss_pages(), 0.8);
        let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
        let engine = Engine::new(IntervalModel::new(MachineModel::default()));
        let res = engine.run(&mut mb, &mut tpp, cap, |_| None);
        let promoted = res.total_promoted();
        let demoted = res.total_demoted();
        assert!(promoted > 0, "churn must drive promotions");
        assert!(demoted > 0, "and kswapd must demote (promoted={promoted})");
        // promotion rate should be in the ballpark of pm_pr per interval
        // (kswapd budget may throttle it below the target)
        let per_interval = promoted as f64 / res.trace.len() as f64;
        assert!(
            per_interval > 10.0 && per_interval < 120.0,
            "pm_pr/interval = {per_interval}"
        );
    }

    #[test]
    fn rss_grows_when_config_underspecifies() {
        let mut c = cfg();
        c.rss_pages = 10.0; // nonsense: smaller than the resident sets
        let mb = Microbench::new(c, 2);
        assert!(mb.rss_pages() as u64 >= mb.page_sets().resident_pages());
    }

    #[test]
    fn even_spread_means_low_per_page_concentration() {
        // §3.2 "Limitation": max per-page count must be tiny (== hot_thr)
        let mut mb = Microbench::new(cfg(), 3);
        let _ = mb.next_interval();
        let p = mb.next_interval().unwrap();
        let max = p.accesses.iter().map(|a| a.total()).max().unwrap();
        assert!(max <= 2 + 1, "max per-page count {max}");
    }
}
