//! Batched multi-run executor: expand a grid specification into run
//! cells, execute them across threads, and derive the paper's reported
//! quantities per cell.
//!
//! Every headline result in the paper (Figs. 1, 3–8, Tables 1–3) is a
//! *sweep* — workloads × fast-memory fractions × seeds × policies — and
//! each comparison needs the same fast-memory-only baseline. This module
//! makes that shape first-class:
//!
//! * [`SweepSpec`] describes the grid (plus run length, machine, thread
//!   budget) and expands to a deterministic list of [`SweepCellSpec`]s;
//! * [`run_sweep`] executes the cells on a scoped-thread worker pool
//!   (no rayon offline) and memoizes the fast-memory-only baselines in a
//!   [`BaselineCache`] keyed by (workload, seed, intervals, hot_thr,
//!   machine), so `F` fractions × `P` policies of one workload cost one
//!   baseline run instead of `F × P`;
//! * [`SweepResult`] returns per-cell [`RunResult`]s with derived loss
//!   (vs the memoized baseline) and fast-memory saving, in grid order
//!   regardless of scheduling — results are byte-for-byte identical for
//!   any thread count.

use std::collections::{HashMap, HashSet};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Result};

use super::{
    overall_loss, run_first_touch, run_fm_only, run_memtis, run_tpp, run_tpp_gated,
    run_tpp_nomad, run_tuna_service, RunSpec,
};
use crate::admission::AdmissionConfig;
use crate::artifact::shard::{LazyShardedNn, LazyShardedPerfDb};
use crate::config::experiment::TunaConfig;
use crate::perfdb::native::{NativeNn, NnQuery};
use crate::perfdb::{PerfDb, PerfSource};
use crate::service::TunerService;
use crate::sim::{MachineModel, MigrationModel, RunResult};
use crate::util::parallel::{default_threads, parallel_map};

/// Page-management policy a sweep cell runs under.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SweepPolicy {
    /// TPP at the cell's fixed fast-memory fraction.
    Tpp,
    /// NUMA first-touch (no migration) at the cell's fraction.
    FirstTouch,
    /// MEMTIS-style dynamic-threshold policy at the cell's fraction.
    Memtis,
    /// TPP + the Tuna tuner (starts at 100% fast memory and shrinks, so
    /// [`SweepSpec::expand`] collapses the fraction axis to a single cell
    /// at `fm_fraction = 1.0`). Requires [`SweepSpec::tuna`].
    Tuna,
    /// TPP under Nomad-style non-exclusive tiering: transactional
    /// promotion copies that abort on write, shadow copies on the slow
    /// tier, free demotions of clean shadowed pages. The cell's migration
    /// mode is forced non-exclusive even when the sweep's migration axis
    /// says `exclusive` (run plain [`SweepPolicy::Tpp`] for that).
    TppNomad,
    /// TPP behind the migration admission-control gate: every promotion
    /// candidate must clear a per-interval bandwidth budget, a
    /// benefit-vs-copy-cost payoff test and a recency-of-demotion
    /// cool-down before it may copy. A cell whose sweep leaves
    /// [`SweepSpec::admission`] disabled is normalized to the enabled
    /// default gate (run plain [`SweepPolicy::Tpp`] for ungated TPP).
    TppGated,
}

impl SweepPolicy {
    /// Every policy, in canonical (on-disk code) order — the single
    /// source of truth for [`Self::parse`]'s error message.
    pub const ALL: [SweepPolicy; 6] = [
        SweepPolicy::Tpp,
        SweepPolicy::FirstTouch,
        SweepPolicy::Memtis,
        SweepPolicy::Tuna,
        SweepPolicy::TppNomad,
        SweepPolicy::TppGated,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SweepPolicy::Tpp => "tpp",
            SweepPolicy::FirstTouch => "first-touch",
            SweepPolicy::Memtis => "memtis",
            SweepPolicy::Tuna => "tuna",
            SweepPolicy::TppNomad => "tpp-nomad",
            SweepPolicy::TppGated => "tpp-gated",
        }
    }

    /// One-line description of what the policy does, for discovery from
    /// CLI error messages and help text.
    pub fn describe(&self) -> &'static str {
        match self {
            SweepPolicy::Tpp => {
                "Linux TPP: hint-fault promotion + watermark demotion at a fixed fast-tier size"
            }
            SweepPolicy::FirstTouch => {
                "NUMA first-touch placement, no migration — the static-placement bound"
            }
            SweepPolicy::Memtis => {
                "MEMTIS-style dynamic hotness threshold targeting fast-tier occupancy"
            }
            SweepPolicy::Tuna => {
                "TPP plus the Tuna tuner shrinking fast memory along the modeled loss curve"
            }
            SweepPolicy::TppNomad => {
                "TPP under Nomad-style non-exclusive transactional page migration"
            }
            SweepPolicy::TppGated => {
                "TPP behind admission control: budgeted, payoff-gated, thrash-resistant promotion"
            }
        }
    }

    /// The policy roster as a `name — description` line per policy,
    /// for multi-line CLI error messages ([`Self::parse`] and the
    /// empty-`policies` branch of [`SweepSpec::expand`] both print it,
    /// so new policies are discoverable at exactly the places a user
    /// types policy names).
    pub fn catalogue() -> String {
        Self::ALL
            .iter()
            .map(|p| format!("  {:<12} {}", p.name(), p.describe()))
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// Parse a CLI-style policy name, case-insensitively. The error
    /// message enumerates every valid name with its one-line description
    /// (derived from [`Self::ALL`], so it can never drift from the
    /// actual policy set).
    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "tpp" => Ok(SweepPolicy::Tpp),
            "first-touch" | "firsttouch" | "first_touch" | "ft" => Ok(SweepPolicy::FirstTouch),
            "memtis" => Ok(SweepPolicy::Memtis),
            "tuna" => Ok(SweepPolicy::Tuna),
            "tpp-nomad" | "tppnomad" | "tpp_nomad" | "nomad" => Ok(SweepPolicy::TppNomad),
            "tpp-gated" | "tppgated" | "tpp_gated" | "gated" => Ok(SweepPolicy::TppGated),
            other => {
                bail!("unknown policy `{other}`; valid policies:\n{}", Self::catalogue())
            }
        }
    }

    /// Stable on-disk code (the artifact store's cell tables use it;
    /// never renumber, only extend).
    pub fn code(&self) -> u8 {
        match self {
            SweepPolicy::Tpp => 0,
            SweepPolicy::FirstTouch => 1,
            SweepPolicy::Memtis => 2,
            SweepPolicy::Tuna => 3,
            SweepPolicy::TppNomad => 4,
            SweepPolicy::TppGated => 5,
        }
    }

    /// Inverse of [`Self::code`].
    pub fn from_code(code: u8) -> Result<Self> {
        Ok(match code {
            0 => SweepPolicy::Tpp,
            1 => SweepPolicy::FirstTouch,
            2 => SweepPolicy::Memtis,
            3 => SweepPolicy::Tuna,
            4 => SweepPolicy::TppNomad,
            5 => SweepPolicy::TppGated,
            other => bail!("unknown policy code {other} in artifact"),
        })
    }
}

/// The performance database behind a sweep's [`SweepPolicy::Tuna`]
/// cells: either the flat in-memory DB (queried brute-force by
/// [`NativeNn`]) or a bounded-resident lazy sharded DB from the artifact
/// store (queried by [`LazyShardedNn`], all cells sharing one segment
/// cache under one [`crate::artifact::shard::ResidencyLimit`]). Both
/// back the shared [`TunerService`] with bit-identical decisions for the
/// same records.
#[derive(Clone, Debug)]
pub enum TunaDb {
    Flat(Arc<PerfDb>),
    Lazy(Arc<LazyShardedPerfDb>),
}

impl TunaDb {
    /// The loss-curve source handed to the tuner service.
    pub fn source(&self) -> Arc<dyn PerfSource> {
        match self {
            TunaDb::Flat(db) => db.clone(),
            TunaDb::Lazy(db) => db.clone(),
        }
    }

    /// A fresh query backend over the same database — called once per
    /// aggregation worker, so sharded services never share a backend.
    /// Queries run on their worker's aggregation thread; the lazy
    /// backend scans its shards serially there (fan-out threads would
    /// fight the sweep's own worker pool), which changes nothing about
    /// the answers.
    pub fn query(&self) -> Box<dyn NnQuery + Send> {
        match self {
            TunaDb::Flat(db) => Box::new(NativeNn::new(db)),
            TunaDb::Lazy(db) => Box::new(LazyShardedNn::new(db.clone(), 1)),
        }
    }
}

/// Grid specification: the cross product of every axis below, one cell
/// per (workload, seed, hot_thr, fraction, policy, migration)
/// combination.
#[derive(Clone, Debug)]
pub struct SweepSpec {
    pub workloads: Vec<String>,
    /// Fast-memory fractions of each workload's peak RSS.
    pub fractions: Vec<f64>,
    pub seeds: Vec<u64>,
    pub hot_thrs: Vec<u32>,
    pub policies: Vec<SweepPolicy>,
    /// Page-migration semantics axis. The default single-element
    /// `[Exclusive]` axis reproduces the pre-axis grid exactly — same
    /// cells, same results — because an `Exclusive` cell defers to the
    /// policy's own model (see [`RunSpec::migration`]).
    pub migrations: Vec<MigrationModel>,
    /// Migration admission-control knob shared by every cell. The
    /// disabled default reproduces the pre-admission grid exactly;
    /// [`SweepPolicy::TppGated`] cells are normalized to the enabled
    /// default gate when this is left disabled (gating is what that
    /// policy *is*), and policies without a gate ([`SweepPolicy::FirstTouch`],
    /// [`SweepPolicy::Memtis`], [`SweepPolicy::TppNomad`]) are normalized
    /// to disabled so their cell specs describe the run truthfully.
    pub admission: AdmissionConfig,
    /// Run length in profiling intervals (shared by every cell).
    pub intervals: u32,
    pub machine: MachineModel,
    /// Worker threads; 0 means "one per available core".
    pub threads: usize,
    /// Database + tuner config, required when `policies` contains
    /// [`SweepPolicy::Tuna`].
    pub tuna: Option<(TunaDb, TunaConfig)>,
    /// Observability handle, cloned into every cell's [`RunSpec`] and
    /// into the shared tuner service. Disabled by default; cell results
    /// are bit-identical either way.
    pub obs: crate::obs::Recorder,
    /// Aggregation workers for the shared tuner service; 0 is
    /// normalized to 1. Sessions are routed by a stable name hash, so
    /// cell results are bit-identical for any worker count.
    pub service_workers: usize,
}

impl Default for SweepSpec {
    fn default() -> Self {
        SweepSpec {
            workloads: Vec::new(),
            fractions: vec![1.0],
            seeds: vec![42],
            hot_thrs: vec![2],
            policies: vec![SweepPolicy::Tpp],
            migrations: vec![MigrationModel::Exclusive],
            admission: AdmissionConfig::default(),
            intervals: 300,
            machine: MachineModel::default(),
            threads: 0,
            tuna: None,
            obs: crate::obs::Recorder::default(),
            service_workers: 1,
        }
    }
}

impl SweepSpec {
    /// A sweep over the given workloads with single-element defaults on
    /// every other axis (seed 42, hot_thr 2, fraction 1.0, TPP).
    pub fn new<S: AsRef<str>, I: IntoIterator<Item = S>>(workloads: I) -> Self {
        SweepSpec {
            workloads: workloads.into_iter().map(|s| s.as_ref().to_string()).collect(),
            ..SweepSpec::default()
        }
    }

    pub fn with_fractions<I: IntoIterator<Item = f64>>(mut self, fractions: I) -> Self {
        self.fractions = fractions.into_iter().collect();
        self
    }

    pub fn with_seeds<I: IntoIterator<Item = u64>>(mut self, seeds: I) -> Self {
        self.seeds = seeds.into_iter().collect();
        self
    }

    pub fn with_hot_thrs<I: IntoIterator<Item = u32>>(mut self, hot_thrs: I) -> Self {
        self.hot_thrs = hot_thrs.into_iter().collect();
        self
    }

    pub fn with_policies<I: IntoIterator<Item = SweepPolicy>>(mut self, policies: I) -> Self {
        self.policies = policies.into_iter().collect();
        self
    }

    pub fn with_migrations<I: IntoIterator<Item = MigrationModel>>(
        mut self,
        migrations: I,
    ) -> Self {
        self.migrations = migrations.into_iter().collect();
        self
    }

    /// Set the migration admission-control knob for every cell of the
    /// sweep (see [`SweepSpec::admission`] for how it is normalized per
    /// policy).
    pub fn with_admission(mut self, admission: AdmissionConfig) -> Self {
        self.admission = admission;
        self
    }

    pub fn with_intervals(mut self, intervals: u32) -> Self {
        self.intervals = intervals;
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_machine(mut self, machine: MachineModel) -> Self {
        self.machine = machine;
        self
    }

    pub fn with_tuna(mut self, db: Arc<PerfDb>, cfg: TunaConfig) -> Self {
        self.tuna = Some((TunaDb::Flat(db), cfg));
        self
    }

    /// As [`Self::with_tuna`], but over any [`TunaDb`] backend — e.g. a
    /// bounded-resident lazy sharded DB from the artifact store.
    pub fn with_tuna_db(mut self, db: TunaDb, cfg: TunaConfig) -> Self {
        self.tuna = Some((db, cfg));
        self
    }

    pub fn with_obs(mut self, obs: crate::obs::Recorder) -> Self {
        self.obs = obs;
        self
    }

    /// Shard the shared tuner service across `workers` aggregation
    /// workers (0 is normalized to 1). Cell results are bit-identical
    /// for any count — sessions are routed by a stable name hash and
    /// each worker gets its own stateless query backend.
    pub fn with_service_workers(mut self, workers: usize) -> Self {
        self.service_workers = workers;
        self
    }

    /// Expand the grid into cells in deterministic order:
    /// workload → seed → hot_thr → fraction → policy → migration.
    ///
    /// Errors on any empty grid dimension, naming it — a silently empty
    /// cross product would let a sweep "succeed" with an empty table.
    ///
    /// [`SweepPolicy::Tuna`] ignores the fixed fraction (the tuner always
    /// starts at 100% and shrinks), so the fraction axis is collapsed for
    /// Tuna cells: one cell per (workload, seed, hot_thr, migration),
    /// recorded at `fm_fraction = 1.0`, instead of `fractions.len()`
    /// identical runs.
    pub fn expand(&self) -> Result<Vec<SweepCellSpec>> {
        let empties = [
            ("workloads", self.workloads.is_empty()),
            ("fractions", self.fractions.is_empty()),
            ("seeds", self.seeds.is_empty()),
            ("hot_thrs", self.hot_thrs.is_empty()),
            ("policies", self.policies.is_empty()),
            ("migrations", self.migrations.is_empty()),
        ];
        for (axis, empty) in empties {
            if empty {
                if axis == "policies" {
                    bail!(
                        "sweep grid dimension `policies` is empty: the cross product \
                         would yield zero cells (give `policies` at least one of):\n{}",
                        SweepPolicy::catalogue()
                    );
                }
                bail!(
                    "sweep grid dimension `{axis}` is empty: the cross product would \
                     yield zero cells (give `{axis}` at least one value)"
                );
            }
        }
        let mut cells = Vec::with_capacity(
            self.workloads.len()
                * self.seeds.len()
                * self.hot_thrs.len()
                * self.fractions.len()
                * self.policies.len()
                * self.migrations.len(),
        );
        for workload in &self.workloads {
            for &seed in &self.seeds {
                for &hot_thr in &self.hot_thrs {
                    for (fi, &fm_fraction) in self.fractions.iter().enumerate() {
                        for &policy in &self.policies {
                            let fm_fraction = if policy == SweepPolicy::Tuna {
                                if fi > 0 {
                                    continue;
                                }
                                1.0
                            } else {
                                fm_fraction
                            };
                            for &migration in &self.migrations {
                                // tpp-nomad is the transactional variant by
                                // definition: normalize an exclusive axis
                                // value to its effective model so the cell
                                // spec describes the run truthfully
                                let migration = match (policy, migration) {
                                    (SweepPolicy::TppNomad, MigrationModel::Exclusive) => {
                                        MigrationModel::non_exclusive_default()
                                    }
                                    (_, m) => m,
                                };
                                // the same truthfulness rule for the
                                // admission knob: tpp-gated *is* the gated
                                // variant, so a disabled sweep-level knob
                                // becomes the enabled default gate; policies
                                // that never install a gate record disabled
                                let admission = match policy {
                                    SweepPolicy::TppGated if !self.admission.enabled => {
                                        AdmissionConfig::enabled_default()
                                    }
                                    SweepPolicy::TppGated
                                    | SweepPolicy::Tpp
                                    | SweepPolicy::Tuna => self.admission,
                                    SweepPolicy::FirstTouch
                                    | SweepPolicy::Memtis
                                    | SweepPolicy::TppNomad => AdmissionConfig::default(),
                                };
                                cells.push(SweepCellSpec {
                                    workload: workload.clone(),
                                    seed,
                                    hot_thr,
                                    fm_fraction,
                                    policy,
                                    migration,
                                    admission,
                                });
                            }
                        }
                    }
                }
            }
        }
        Ok(cells)
    }
}

/// One cell of the expanded grid.
#[derive(Clone, Debug)]
pub struct SweepCellSpec {
    pub workload: String,
    pub seed: u64,
    pub hot_thr: u32,
    pub fm_fraction: f64,
    pub policy: SweepPolicy,
    /// Page-migration semantics this cell runs under.
    /// [`MigrationModel::Exclusive`] defers to the policy's own model.
    pub migration: MigrationModel,
    /// Migration admission-control knob this cell runs under, already
    /// normalized per policy by [`SweepSpec::expand`] (enabled default
    /// for [`SweepPolicy::TppGated`] under a disabled sweep knob;
    /// disabled for policies that never install a gate).
    pub admission: AdmissionConfig,
}

impl SweepCellSpec {
    /// The coordinator [`RunSpec`] this cell executes.
    pub fn run_spec(&self, sweep: &SweepSpec) -> RunSpec {
        RunSpec {
            workload: self.workload.clone(),
            seed: self.seed,
            intervals: sweep.intervals,
            fm_fraction: self.fm_fraction,
            hot_thr: self.hot_thr,
            machine: sweep.machine.clone(),
            migration: self.migration,
            admission: self.admission,
            obs: sweep.obs.clone(),
        }
    }
}

/// Cache key for a fast-memory-only baseline. The baseline run depends on
/// the workload instance (name + seed + intervals), the promotion
/// threshold and the machine model — but *not* on the cell's fraction or
/// policy, which is exactly why the cache pays off across a grid.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BaselineKey {
    pub workload: String,
    pub seed: u64,
    pub intervals: u32,
    pub hot_thr: u32,
    /// `Debug` fingerprint of the machine model (its f64 fields are not
    /// `Eq`/`Hash`; the fingerprint is exact for identical models).
    pub machine: String,
}

/// How far past a file's mtime the clock must be before a (len, mtime,
/// inode) stamp can be trusted: inside this window an in-place rewrite
/// can land on the *same* stamp (filesystem mtime granularity is as
/// coarse as one second), so the memo re-hashes instead.
const MTIME_SLACK: std::time::Duration = std::time::Duration::from_secs(2);

/// Content fingerprint of a trace file, memoized per (inode, len,
/// mtime): [`BaselineKey::of`] runs roughly twice per sweep cell, and a
/// sweep over a large recorded trace must not re-read megabytes on every
/// cache lookup.
///
/// Invalidation has to be airtight — a stale fingerprint silently skews
/// every loss number derived from the cached baseline — so the memo
/// guards all three rewrite shapes:
/// * atomic-rename rewrites (how `trace::format` saves) change the
///   inode, which is part of the stamp;
/// * in-place rewrites that change length or mtime change the stamp;
/// * in-place rewrites *inside mtime granularity* (same length, same
///   mtime, same inode) are caught by distrusting any memo entry whose
///   hash was taken within [`MTIME_SLACK`] of the file's mtime — such an
///   entry re-hashes on every lookup until the file is old enough that a
///   same-stamp rewrite is impossible.
///
/// The guard errs toward re-hashing: a file whose mtime sits *ahead* of
/// the local clock (NFS skew, a trace copied from another machine) never
/// looks settled, so every lookup re-reads it — slower, never stale.
/// Each re-hash refreshes `hashed_at`, so bounded skew self-heals once
/// the local clock passes `mtime + MTIME_SLACK`.
fn trace_content_key(path: &str) -> Option<String> {
    use std::sync::OnceLock;
    use std::time::SystemTime;
    #[derive(Clone)]
    struct Entry {
        len: u64,
        mtime: SystemTime,
        ino: u64,
        /// Wall clock at hash time, for the granularity guard.
        hashed_at: SystemTime,
        key: String,
    }
    type Memo = Mutex<HashMap<String, Entry>>;
    static MEMO: OnceLock<Memo> = OnceLock::new();

    fn inode_of(meta: &std::fs::Metadata) -> u64 {
        #[cfg(unix)]
        {
            std::os::unix::fs::MetadataExt::ino(meta)
        }
        #[cfg(not(unix))]
        {
            let _ = meta;
            0
        }
    }

    let meta = std::fs::metadata(path).ok()?;
    let (len, mtime, ino) = (meta.len(), meta.modified().ok()?, inode_of(&meta));
    let memo = MEMO.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(e) = memo.lock().unwrap().get(path) {
        let stamp_matches = e.len == len && e.mtime == mtime && e.ino == ino;
        let settled = e
            .hashed_at
            .duration_since(e.mtime)
            .map(|age| age > MTIME_SLACK)
            .unwrap_or(false);
        if stamp_matches && settled {
            return Some(e.key.clone());
        }
    }
    let bytes = std::fs::read(path).ok()?;
    let key = format!("trace:{:016x}", crate::artifact::fnv1a64(&bytes));
    memo.lock().unwrap().insert(
        path.to_string(),
        Entry { len, mtime, ino, hashed_at: SystemTime::now(), key: key.clone() },
    );
    Some(key)
}

impl BaselineKey {
    pub fn of(spec: &RunSpec) -> Self {
        // `trace:FILE` workloads are keyed by the trace's *content*, not
        // its path: re-recording a file in place (new seed, new spec)
        // must miss the cache, and identical traces at different paths
        // should hit it. An unreadable file keeps the path key — the run
        // itself will surface the I/O error.
        let workload = match spec.workload.strip_prefix("trace:") {
            Some(path) => trace_content_key(path)
                .unwrap_or_else(|| spec.workload.to_ascii_lowercase()),
            None => spec.workload.to_ascii_lowercase(),
        };
        BaselineKey {
            workload,
            seed: spec.seed,
            intervals: spec.intervals,
            hot_thr: spec.hot_thr,
            machine: format!("{:?}", spec.machine),
        }
    }
}

/// Thread-safe memo of fast-memory-only baseline runs. Shareable across
/// sweeps (e.g. a bench that runs several grids over the same workloads),
/// and optionally backed by the artifact store ([`Self::persistent`]) so
/// the memo survives the process: a repeated bench or sweep invocation
/// loads baselines from disk instead of re-simulating them.
#[derive(Default)]
pub struct BaselineCache {
    entries: Mutex<HashMap<BaselineKey, Arc<RunResult>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    disk_hits: AtomicUsize,
    disk: Option<crate::artifact::cache::DiskBaselineCache>,
    obs: crate::obs::Recorder,
}

impl BaselineCache {
    pub fn new() -> Self {
        BaselineCache::default()
    }

    /// A cache whose entries are written through to (and on miss loaded
    /// from) one `.bl` artifact per key under `dir` — the cross-process
    /// tier of the memo.
    pub fn persistent(dir: &Path) -> Result<Self> {
        Ok(BaselineCache {
            disk: Some(crate::artifact::cache::DiskBaselineCache::open(dir)?),
            ..BaselineCache::default()
        })
    }

    /// Attach an observability recorder (persist failures become
    /// structured warn-events instead of bare stderr lines).
    pub fn with_obs(mut self, obs: crate::obs::Recorder) -> Self {
        self.obs = obs;
        self
    }

    /// The baseline for `spec` (any fraction): in-memory memo first, then
    /// the disk tier (if persistent), then computed — and written through
    /// to disk so the *next* process skips the simulation.
    pub fn get_or_compute(&self, spec: &RunSpec) -> Result<Arc<RunResult>> {
        let key = BaselineKey::of(spec);
        if let Some(hit) = self.entries.lock().unwrap().get(&key).cloned() {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(hit);
        }
        if let Some(disk) = &self.disk {
            if let Some(loaded) = disk.load_with_obs(&key, &self.obs) {
                self.disk_hits.fetch_add(1, Ordering::Relaxed);
                let loaded = Arc::new(loaded);
                let mut map = self.entries.lock().unwrap();
                return Ok(map.entry(key).or_insert(loaded).clone());
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        // Computed outside the lock; on a concurrent race both sides
        // produce bit-identical results (runs are deterministic), so
        // keeping the first insertion is safe — and racing writers of the
        // same artifact produce identical bytes behind an atomic rename.
        let computed = Arc::new(run_fm_only(spec)?);
        if let Some(disk) = &self.disk {
            if let Err(e) = disk.store(&key, &computed) {
                self.obs
                    .warn("sweep.baseline", &format!("failed to persist baseline artifact: {e:#}"));
            }
        }
        let mut map = self.entries.lock().unwrap();
        Ok(map.entry(key).or_insert(computed).clone())
    }

    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Baselines served from the disk tier (always 0 for in-memory-only
    /// caches).
    pub fn disk_hits(&self) -> usize {
        self.disk_hits.load(Ordering::Relaxed)
    }

    pub fn len(&self) -> usize {
        self.entries.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Extra per-cell statistics for [`SweepPolicy::Tuna`] cells.
#[derive(Clone, Debug)]
pub struct TunaCellStats {
    pub decisions: usize,
    pub mean_fraction: f64,
    pub min_fraction: f64,
    pub decide_ns: u128,
}

/// One executed cell: the full run plus the derived paper quantities.
#[derive(Clone, Debug)]
pub struct SweepCell {
    pub spec: SweepCellSpec,
    pub result: RunResult,
    /// Loss vs the memoized fast-memory-only baseline (allocation epoch
    /// excluded), i.e. [`overall_loss`].
    pub loss: f64,
    /// Fast-memory saving: `1 − fm_fraction` for fixed-size cells; the
    /// mean saving across decisions for Tuna cells.
    pub saving: f64,
    /// Present only for [`SweepPolicy::Tuna`] cells.
    pub tuna: Option<TunaCellStats>,
}

/// Result of one sweep, cells in grid order (see [`SweepSpec::expand`]).
#[derive(Debug)]
pub struct SweepResult {
    pub cells: Vec<SweepCell>,
    /// Distinct baselines actually run for this sweep.
    pub baselines_computed: usize,
    /// Baseline cache hits during this sweep (one per cell).
    pub baseline_hits: usize,
    /// Baselines loaded from the artifact store's disk tier (0 unless the
    /// sweep ran against a [`BaselineCache::persistent`] cache).
    pub baseline_disk_hits: usize,
    /// Wall-clock time of the whole sweep.
    pub wall_ns: u128,
}

impl SweepResult {
    pub fn len(&self) -> usize {
        self.cells.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cells.is_empty()
    }

    /// Find a cell by workload (case-insensitive), policy and fraction.
    pub fn cell(&self, workload: &str, policy: SweepPolicy, fraction: f64) -> Option<&SweepCell> {
        self.cells.iter().find(|c| {
            c.spec.workload.eq_ignore_ascii_case(workload)
                && c.spec.policy == policy
                && (c.spec.fm_fraction - fraction).abs() < 1e-9
        })
    }
}

/// Execute a sweep with a private baseline cache.
pub fn run_sweep(spec: &SweepSpec) -> Result<SweepResult> {
    run_sweep_with_cache(spec, &BaselineCache::new())
}

/// Execute a sweep against a caller-owned [`BaselineCache`] (reusable
/// across several grids over the same workloads).
///
/// Tuna cells do not each build a tuner: every Tuna cell of the sweep is
/// a session on **one shared channel-mode [`TunerService`]** (stood up
/// here, torn down when the sweep returns), so baseline simulations and
/// Tuna runs concurrently feed its aggregation workers
/// ([`SweepSpec::service_workers`], one by default; sessions are routed
/// by a stable name hash). Decisions stay bit-identical to the in-loop
/// path for any thread or worker count — the per-session state is the
/// in-loop tuner's, and each worker's nearest-neighbour backend is
/// stateless.
pub fn run_sweep_with_cache(spec: &SweepSpec, cache: &BaselineCache) -> Result<SweepResult> {
    let cells = spec.expand()?;
    let has_tuna = cells.iter().any(|c| c.policy == SweepPolicy::Tuna);
    if has_tuna && spec.tuna.is_none() {
        bail!("SweepPolicy::Tuna requires SweepSpec::tuna (performance database + TunaConfig)");
    }
    let service = match &spec.tuna {
        Some((db, _)) if has_tuna => Some(TunerService::spawn_sharded_with_obs(
            db.source(),
            |_| db.query(),
            spec.service_workers,
            spec.obs.clone(),
        )),
        _ => None,
    };
    let threads = if spec.threads == 0 { default_threads() } else { spec.threads };
    let hits0 = cache.hits();
    let misses0 = cache.misses();
    let disk_hits0 = cache.disk_hits();
    let t0 = Instant::now();

    // Phase 1: warm the baseline cache, one run per distinct key, in
    // parallel. Keys are unique here so no worker ever duplicates work.
    let mut seen = HashSet::new();
    let mut base_specs: Vec<RunSpec> = Vec::new();
    for c in &cells {
        let rs = c.run_spec(spec);
        if seen.insert(BaselineKey::of(&rs)) {
            base_specs.push(rs);
        }
    }
    parallel_map(base_specs.len(), threads, |i| {
        cache.get_or_compute(&base_specs[i]).map(|_| ())
    })
    .into_iter()
    .collect::<Result<Vec<()>>>()?;

    // Phase 2: every grid cell in parallel; baselines all hit the cache.
    let out: Vec<SweepCell> = parallel_map(cells.len(), threads, |i| {
        let c = &cells[i];
        let rs = c.run_spec(spec);
        let baseline = cache.get_or_compute(&rs)?;
        // measured only when recording — the disabled path stays free
        let cell_t0 = spec.obs.is_enabled().then(Instant::now);
        let (result, tuna) = match c.policy {
            SweepPolicy::Tpp => (run_tpp(&rs)?, None),
            SweepPolicy::FirstTouch => (run_first_touch(&rs)?, None),
            SweepPolicy::Memtis => (run_memtis(&rs)?, None),
            SweepPolicy::TppNomad => (run_tpp_nomad(&rs)?, None),
            SweepPolicy::TppGated => (run_tpp_gated(&rs)?, None),
            SweepPolicy::Tuna => {
                let (_, cfg) = spec.tuna.as_ref().expect("checked above");
                let svc = service.as_ref().expect("created above");
                let run = run_tuna_service(&rs, svc, cfg)?;
                let stats = TunaCellStats {
                    decisions: run.decisions.len(),
                    mean_fraction: run.mean_fraction,
                    min_fraction: run.min_fraction,
                    decide_ns: run.decide_ns,
                };
                (run.result, Some(stats))
            }
        };
        let loss = overall_loss(&result, &baseline);
        let saving = match &tuna {
            Some(s) => 1.0 - s.mean_fraction,
            None => 1.0 - c.fm_fraction,
        };
        if let Some(t0) = cell_t0 {
            use crate::obs::{EventKind, NS_BUCKETS};
            let wall_ns = t0.elapsed().as_nanos() as u64;
            spec.obs.count("sweep_cells_total", 1);
            spec.obs.observe("sweep_cell_wall_ns", NS_BUCKETS, wall_ns as f64);
            spec.obs.record(EventKind::SweepCell {
                workload: c.workload.clone(),
                policy: c.policy.name().to_string(),
                fraction: c.fm_fraction,
                seed: c.seed,
                wall_ns,
            });
        }
        Ok(SweepCell { spec: c.clone(), result, loss, saving, tuna })
    })
    .into_iter()
    .collect::<Result<Vec<SweepCell>>>()?;

    Ok(SweepResult {
        cells: out,
        baselines_computed: cache.misses() - misses0,
        baseline_hits: cache.hits() - hits0,
        baseline_disk_hits: cache.disk_hits() - disk_hits0,
        wall_ns: t0.elapsed().as_nanos(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(workloads: &[&str]) -> SweepSpec {
        SweepSpec::new(workloads.iter().copied()).with_intervals(20)
    }

    #[test]
    fn expand_is_deterministic_grid_order() {
        let spec = tiny(&["BFS", "Btree"])
            .with_fractions([0.9, 0.8])
            .with_policies([SweepPolicy::Tpp, SweepPolicy::FirstTouch]);
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2 * 2 * 2);
        assert_eq!(cells[0].workload, "BFS");
        assert_eq!(cells[0].fm_fraction, 0.9);
        assert_eq!(cells[0].policy, SweepPolicy::Tpp);
        assert_eq!(cells[1].policy, SweepPolicy::FirstTouch);
        assert_eq!(cells[2].fm_fraction, 0.8);
        assert_eq!(cells[4].workload, "Btree");
        // expand twice → identical
        let again = spec.expand().unwrap();
        for (a, b) in cells.iter().zip(&again) {
            assert_eq!(format!("{a:?}"), format!("{b:?}"));
        }
    }

    #[test]
    fn tuna_cells_collapse_the_fraction_axis() {
        let spec = tiny(&["Btree"])
            .with_fractions([0.9, 0.8, 0.7])
            .with_policies([SweepPolicy::Tpp, SweepPolicy::Tuna]);
        let cells = spec.expand().unwrap();
        // 3 Tpp cells + exactly one Tuna cell (run_tuna ignores the fixed
        // fraction, so duplicating it across the axis would waste runs).
        assert_eq!(cells.len(), 4);
        let tuna: Vec<_> =
            cells.iter().filter(|c| c.policy == SweepPolicy::Tuna).collect();
        assert_eq!(tuna.len(), 1);
        assert_eq!(tuna[0].fm_fraction, 1.0);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in SweepPolicy::ALL {
            assert_eq!(SweepPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(SweepPolicy::parse("nope").is_err());
    }

    #[test]
    fn policy_parse_is_case_insensitive_and_lists_valid_names() {
        for (alias, want) in [
            ("TPP", SweepPolicy::Tpp),
            ("Tuna", SweepPolicy::Tuna),
            ("MEMTIS", SweepPolicy::Memtis),
            ("First-Touch", SweepPolicy::FirstTouch),
            ("FIRSTTOUCH", SweepPolicy::FirstTouch),
            ("fT", SweepPolicy::FirstTouch),
            (" tpp ", SweepPolicy::Tpp),
            ("TPP-Nomad", SweepPolicy::TppNomad),
            ("nomad", SweepPolicy::TppNomad),
            ("tpp_nomad", SweepPolicy::TppNomad),
            ("TPP-Gated", SweepPolicy::TppGated),
            ("gated", SweepPolicy::TppGated),
            ("tpp_gated", SweepPolicy::TppGated),
        ] {
            assert_eq!(SweepPolicy::parse(alias).unwrap(), want, "alias `{alias}`");
        }
        let msg = format!("{:#}", SweepPolicy::parse("bogus").unwrap_err());
        for p in SweepPolicy::ALL {
            assert!(msg.contains(p.name()), "error must list `{}`: {msg}", p.name());
            assert!(
                msg.contains(p.describe()),
                "error must describe `{}`: {msg}",
                p.name()
            );
        }
    }

    #[test]
    fn expand_names_the_empty_dimension() {
        let cases: [(&str, SweepSpec); 5] = [
            ("workloads", SweepSpec::new(Vec::<String>::new())),
            ("fractions", tiny(&["BFS"]).with_fractions([])),
            ("seeds", tiny(&["BFS"]).with_seeds([])),
            ("hot_thrs", tiny(&["BFS"]).with_hot_thrs([])),
            ("policies", tiny(&["BFS"]).with_policies([])),
        ];
        for (axis, spec) in cases {
            let msg = format!("{:#}", spec.expand().unwrap_err());
            assert!(msg.contains(axis), "error for empty `{axis}` must name it: {msg}");
        }
        // the policies axis additionally prints the policy roster, so
        // `tpp-gated` and friends are discoverable from the error itself
        let msg = format!("{:#}", tiny(&["BFS"]).with_policies([]).expand().unwrap_err());
        for p in SweepPolicy::ALL {
            assert!(msg.contains(p.name()), "empty-policies error must list `{}`: {msg}", p.name());
            assert!(
                msg.contains(p.describe()),
                "empty-policies error must describe `{}`: {msg}",
                p.name()
            );
        }
    }

    #[test]
    fn baselines_are_memoized_across_fractions_and_policies() {
        let spec = tiny(&["Btree"])
            .with_fractions([0.9, 0.8, 0.7])
            .with_policies([SweepPolicy::Tpp, SweepPolicy::FirstTouch])
            .with_threads(2);
        let res = run_sweep(&spec).unwrap();
        assert_eq!(res.len(), 6);
        assert_eq!(res.baselines_computed, 1, "one workload → one baseline");
        assert_eq!(res.baseline_hits, 6, "every cell reuses it");
    }

    #[test]
    fn cell_losses_match_direct_runs() {
        let spec = tiny(&["BFS"]).with_fractions([0.8]);
        let res = run_sweep(&spec).unwrap();
        let cell = res.cell("BFS", SweepPolicy::Tpp, 0.8).unwrap();

        let rs = RunSpec::new("BFS").with_intervals(20).with_fraction(0.8);
        let direct = run_tpp(&rs).unwrap();
        let base = run_fm_only(&rs).unwrap();
        assert_eq!(cell.result.total_ns, direct.total_ns);
        assert_eq!(cell.loss, overall_loss(&direct, &base));
        assert!((cell.saving - 0.2).abs() < 1e-12);
    }

    #[test]
    fn distinct_seeds_and_hot_thrs_get_distinct_baselines() {
        let spec = tiny(&["Btree"])
            .with_fractions([0.85])
            .with_seeds([1, 2])
            .with_hot_thrs([2, 4]);
        let res = run_sweep(&spec).unwrap();
        assert_eq!(res.len(), 4);
        assert_eq!(res.baselines_computed, 4, "seed × hot_thr keys differ");
    }

    #[test]
    fn empty_grid_and_missing_tuna_config_are_errors() {
        let empty = SweepSpec::new(Vec::<String>::new());
        assert!(run_sweep(&empty).is_err());
        let no_db = tiny(&["BFS"]).with_policies([SweepPolicy::Tuna]);
        assert!(run_sweep(&no_db).is_err());
    }

    #[test]
    fn unknown_workload_surfaces_the_run_error() {
        let spec = tiny(&["not-a-workload"]);
        assert!(run_sweep(&spec).is_err());
    }

    #[test]
    fn policy_codes_roundtrip_and_reject_unknown() {
        for p in SweepPolicy::ALL {
            assert_eq!(SweepPolicy::from_code(p.code()).unwrap(), p);
        }
        assert_eq!(SweepPolicy::TppNomad.code(), 4, "on-disk codes are frozen");
        assert_eq!(SweepPolicy::TppGated.code(), 5, "on-disk codes are frozen");
        assert!(SweepPolicy::from_code(200).is_err());
    }

    #[test]
    fn migration_axis_crosses_innermost_and_defaults_to_exclusive() {
        // default axis: every cell is Exclusive and the grid is unchanged
        let spec = tiny(&["BFS"]).with_fractions([0.9, 0.8]);
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 2);
        assert!(cells.iter().all(|c| c.migration.is_exclusive()));

        // explicit two-mode axis doubles the grid, migration innermost
        let nx = MigrationModel::non_exclusive_default();
        let spec = spec.with_migrations([MigrationModel::Exclusive, nx]);
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 4);
        assert!(cells[0].migration.is_exclusive());
        assert_eq!(cells[1].migration, nx);
        assert_eq!(cells[0].fm_fraction, cells[1].fm_fraction);
        assert_eq!(cells[2].fm_fraction, 0.8);

        // empty axis is named like the others
        let msg =
            format!("{:#}", tiny(&["BFS"]).with_migrations([]).expand().unwrap_err());
        assert!(msg.contains("migrations"), "{msg}");
    }

    #[test]
    fn expand_normalizes_the_admission_knob_per_policy() {
        // disabled sweep knob: gated cells get the enabled default, every
        // other policy records disabled — the pre-admission grid exactly
        let spec = tiny(&["BFS"]).with_policies([
            SweepPolicy::Tpp,
            SweepPolicy::Memtis,
            SweepPolicy::TppNomad,
            SweepPolicy::TppGated,
        ]);
        let cells = spec.expand().unwrap();
        assert_eq!(cells.len(), 4);
        assert_eq!(cells[0].admission, AdmissionConfig::default());
        assert_eq!(cells[1].admission, AdmissionConfig::default());
        assert_eq!(cells[2].admission, AdmissionConfig::default());
        assert_eq!(cells[3].policy, SweepPolicy::TppGated);
        assert_eq!(cells[3].admission, AdmissionConfig::enabled_default());

        // enabled sweep knob: gate-capable policies carry it verbatim,
        // gate-less policies still record disabled (their runs ignore it)
        let custom = AdmissionConfig {
            enabled: true,
            budget_pages: 64,
            cooldown_intervals: 8,
            horizon_intervals: 16,
        };
        let cells = spec.with_admission(custom).expand().unwrap();
        assert_eq!(cells[0].admission, custom, "tpp carries the enabled knob");
        assert_eq!(cells[1].admission, AdmissionConfig::default(), "memtis has no gate");
        assert_eq!(cells[2].admission, AdmissionConfig::default(), "nomad has no gate");
        assert_eq!(cells[3].admission, custom, "tpp-gated keeps the custom gate");
    }

    #[test]
    fn exclusive_cells_are_bit_identical_when_gated_rides_along() {
        let plain = run_sweep(&tiny(&["Btree"]).with_fractions([0.8])).unwrap();
        let mixed = run_sweep(
            &tiny(&["Btree"])
                .with_fractions([0.8])
                .with_policies([SweepPolicy::Tpp, SweepPolicy::TppGated])
                .with_threads(2),
        )
        .unwrap();
        assert_eq!(mixed.len(), 2);
        let tpp = mixed.cell("Btree", SweepPolicy::Tpp, 0.8).unwrap();
        let base = plain.cell("Btree", SweepPolicy::Tpp, 0.8).unwrap();
        assert_eq!(tpp.result.total_ns.to_bits(), base.result.total_ns.to_bits());
        assert_eq!(tpp.loss.to_bits(), base.loss.to_bits());
        assert_eq!(tpp.result.total_admission_verdicts(), 0, "ungated tpp installs no gate");

        let gated = mixed.cell("Btree", SweepPolicy::TppGated, 0.8).unwrap();
        assert_eq!(gated.result.policy, "tpp-gated");
        assert!(
            gated.result.total_admission_verdicts() > 0,
            "gated sweep cell must exercise the admission gate"
        );
        // one workload instance → one shared baseline across both cells
        assert_eq!(mixed.baselines_computed, 1);
    }

    #[test]
    fn exclusive_cells_are_bit_identical_when_nomad_rides_along() {
        let plain = run_sweep(&tiny(&["Btree"]).with_fractions([0.8])).unwrap();
        let mixed = run_sweep(
            &tiny(&["Btree"])
                .with_fractions([0.8])
                .with_policies([SweepPolicy::Tpp, SweepPolicy::TppNomad])
                .with_threads(2),
        )
        .unwrap();
        assert_eq!(mixed.len(), 2);
        let tpp = mixed.cell("Btree", SweepPolicy::Tpp, 0.8).unwrap();
        let base = plain.cell("Btree", SweepPolicy::Tpp, 0.8).unwrap();
        assert_eq!(tpp.result.total_ns.to_bits(), base.result.total_ns.to_bits());
        assert_eq!(tpp.loss.to_bits(), base.loss.to_bits());

        let nomad = mixed.cell("Btree", SweepPolicy::TppNomad, 0.8).unwrap();
        assert_eq!(nomad.result.policy, "tpp-nomad");
        let c = nomad.result.total_migration_counters();
        assert!(
            c.shadow_hits + c.shadow_free_demotions + c.txn_aborts > 0,
            "nomad sweep cell must exercise transactional accounting: {c:?}"
        );
        // one workload instance → one shared baseline across both cells
        assert_eq!(mixed.baselines_computed, 1);
    }

    #[test]
    fn migration_axis_shifts_results_for_stock_tpp() {
        let nx = MigrationModel::non_exclusive_default();
        let res = run_sweep(
            &tiny(&["kv-drift"])
                .with_fractions([0.6])
                .with_migrations([MigrationModel::Exclusive, nx]),
        )
        .unwrap();
        assert_eq!(res.len(), 2);
        let excl = &res.cells[0];
        let non = &res.cells[1];
        assert!(excl.spec.migration.is_exclusive());
        let ec = excl.result.total_migration_counters();
        assert_eq!((ec.shadow_hits, ec.txn_aborts), (0, 0));
        let nc = non.result.total_migration_counters();
        assert!(
            nc.shadow_hits + nc.shadow_free_demotions + nc.txn_aborts > 0,
            "non-exclusive cell must differ: {nc:?}"
        );
        // both modes share the one fm-only baseline
        assert_eq!(res.baselines_computed, 1);
    }

    #[test]
    fn trace_content_key_catches_same_length_same_mtime_rewrite() {
        // The stale-baseline window: rewrite a trace in place with the
        // same byte length, same inode and (thanks to mtime granularity)
        // the same mtime. The old memo served the stale fingerprint; the
        // granularity guard must re-hash and see the new content.
        let path = std::env::temp_dir().join(format!("tuna_trc_stale_{}.trc", std::process::id()));
        std::fs::write(&path, vec![b'a'; 4096]).unwrap();
        let key = |p: &std::path::Path| trace_content_key(p.to_str().unwrap()).unwrap();
        let k1 = key(&path);
        assert_eq!(k1, key(&path), "same content must keep its fingerprint");
        let mtime = std::fs::metadata(&path).unwrap().modified().unwrap();
        // in-place rewrite (same inode, same length)...
        std::fs::write(&path, vec![b'b'; 4096]).unwrap();
        // ...pinned to the original mtime, simulating a rewrite that
        // landed inside the filesystem's mtime tick
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_modified(mtime)
            .unwrap();
        let k2 = key(&path);
        assert_ne!(k1, k2, "same-stamp rewrite was served a stale fingerprint");
        // and the re-hashed key is itself stable
        assert_eq!(k2, key(&path));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn persistent_cache_survives_a_fresh_process_image() {
        let dir = std::env::temp_dir()
            .join(format!("tuna_sweep_persist_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let spec = RunSpec::new("Btree").with_intervals(20);

        let first = BaselineCache::persistent(&dir).unwrap();
        let a = first.get_or_compute(&spec).unwrap();
        assert_eq!((first.misses(), first.disk_hits()), (1, 0));

        // a fresh cache over the same directory stands in for a fresh
        // process: it must load from disk without re-simulating
        let second = BaselineCache::persistent(&dir).unwrap();
        let b = second.get_or_compute(&spec).unwrap();
        assert_eq!((second.misses(), second.disk_hits()), (0, 1));
        assert_eq!(a.total_ns.to_bits(), b.total_ns.to_bits());
        assert_eq!(a.trace.len(), b.trace.len());
        for (x, y) in a.trace.iter().zip(&b.trace) {
            assert_eq!(x.wall_ns.to_bits(), y.wall_ns.to_bits());
            assert_eq!(x.promoted, y.promoted);
        }
        // and the in-memory tier serves the third lookup
        let _ = second.get_or_compute(&spec).unwrap();
        assert_eq!(second.hits(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn concurrent_writers_of_one_key_yield_one_valid_artifact() {
        let dir = std::env::temp_dir()
            .join(format!("tuna_sweep_race_{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let spec = RunSpec::new("Btree").with_intervals(20);

        // two independent persistent caches (two "processes") race to
        // compute and persist the same key
        let results: Vec<Arc<RunResult>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let dir = &dir;
                    let spec = &spec;
                    s.spawn(move || {
                        BaselineCache::persistent(dir).unwrap().get_or_compute(spec).unwrap()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(results[0].total_ns.to_bits(), results[1].total_ns.to_bits());

        // exactly one artifact file remains, and it parses cleanly with
        // results identical to both writers'
        let files: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().path())
            .filter(|p| p.extension().map(|e| e == "bl").unwrap_or(false))
            .collect();
        assert_eq!(files.len(), 1, "one key -> one artifact, got {files:?}");
        let reader = BaselineCache::persistent(&dir).unwrap();
        let loaded = reader.get_or_compute(&spec).unwrap();
        assert_eq!(reader.disk_hits(), 1);
        assert_eq!(loaded.total_ns.to_bits(), results[0].total_ns.to_bits());
        std::fs::remove_dir_all(&dir).ok();
    }
}
