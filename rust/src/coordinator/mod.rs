//! The L3 coordinator: wires workloads, the TPP policy, the simulator
//! engine, telemetry and the Tuna tuner into complete runs, and derives
//! the paper's reported quantities (perf loss vs the fast-memory-only
//! baseline, fast-memory savings, migration counts).
//!
//! This is the module examples and benches drive; nothing here touches
//! Python — the only external dependency is the AOT HLO artifact loaded
//! through [`crate::runtime`] when the XLA query path is enabled.

pub mod sweep;

pub use sweep::{
    run_sweep, run_sweep_with_cache, BaselineCache, SweepCell, SweepPolicy, SweepResult,
    SweepSpec, TunaDb,
};

use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::admission::AdmissionConfig;
use crate::config::experiment::TunaConfig;
use crate::outcome::OutcomeRecord;
use crate::perfdb::native::{NativeNn, NnQuery};
use crate::perfdb::{PerfDb, PerfSource};
use crate::service::{Event, SessionSpec, TunerService};
use crate::sim::{Engine, IntervalModel, MachineModel, MigrationModel, RunResult};
use crate::tpp::{FirstTouch, Tpp, TppGated, TppNomad, Watermarks};
use crate::tuner::{Decision, Tuner};
use crate::workloads::{self, Workload};

/// What to run and under which policy.
#[derive(Clone, Debug)]
pub struct RunSpec {
    /// Table 1 workload name (or "microbench" via the library API).
    pub workload: String,
    pub seed: u64,
    pub intervals: u32,
    /// Fast-memory size as a fraction of the workload's peak RSS.
    pub fm_fraction: f64,
    pub hot_thr: u32,
    pub machine: MachineModel,
    /// Migration semantics for the run. [`MigrationModel::Exclusive`]
    /// (the default) defers to the policy's own preference, so stock
    /// policies behave exactly as pre-refactor and `tpp-nomad` gets its
    /// transactional mode; a non-exclusive value overrides any policy.
    pub migration: MigrationModel,
    /// Migration admission-control knob. Disabled (the default) installs
    /// no gate anywhere, reproducing pre-admission runs bit-for-bit; an
    /// enabled config gates every Tpp-based run of this spec ([`run_tpp`],
    /// [`run_tpp_gated`], the Tuna paths). Gate-less policies
    /// ([`run_first_touch`], [`run_memtis`], [`run_tpp_nomad`]) ignore it.
    pub admission: AdmissionConfig,
    /// Observability handle, threaded into the engine (and, for Tuna
    /// runs, the tuner) exactly like `migration`: disabled by default,
    /// and proven not to perturb any run it observes.
    pub obs: crate::obs::Recorder,
}

impl RunSpec {
    pub fn new(workload: &str) -> Self {
        RunSpec {
            workload: workload.to_string(),
            seed: 42,
            intervals: 300,
            fm_fraction: 1.0,
            hot_thr: 2,
            machine: MachineModel::default(),
            migration: MigrationModel::Exclusive,
            admission: AdmissionConfig::default(),
            obs: crate::obs::Recorder::default(),
        }
    }

    pub fn with_fraction(mut self, f: f64) -> Self {
        self.fm_fraction = f;
        self
    }

    pub fn with_intervals(mut self, n: u32) -> Self {
        self.intervals = n;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_migration(mut self, migration: MigrationModel) -> Self {
        self.migration = migration;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionConfig) -> Self {
        self.admission = admission;
        self
    }

    pub fn with_obs(mut self, obs: crate::obs::Recorder) -> Self {
        self.obs = obs;
        self
    }

    fn make_workload(&self) -> Result<Box<dyn Workload>> {
        workloads::by_name(&self.workload, self.seed, self.intervals)
    }

    fn engine(&self) -> Engine {
        let mut engine = Engine::new(IntervalModel::new(self.machine.clone()));
        engine.migration = match self.migration {
            MigrationModel::Exclusive => None, // defer to the policy
            m => Some(m),
        };
        engine.obs = self.obs.clone();
        engine
    }
}

/// Run under TPP at the spec's fast-memory fraction (no Tuna). An
/// enabled [`RunSpec::admission`] gates promotions; the disabled default
/// installs no gate and is bit-identical to the pre-admission run.
pub fn run_tpp(spec: &RunSpec) -> Result<RunResult> {
    let mut w = spec.make_workload()?;
    let cap = Engine::fm_capacity(w.rss_pages(), spec.fm_fraction);
    let mut tpp = Tpp::with_hot_thr(Watermarks::default_for_capacity(cap), spec.hot_thr)
        .with_admission(spec.admission);
    tpp.scan_budget = spec.machine.promote_scan_pages_per_interval;
    Ok(spec.engine().run(w.as_mut(), &mut tpp, cap, |_| None))
}

/// Run under the NUMA first-touch baseline (no migration) — Fig. 1's
/// "w/o TPP" configuration.
pub fn run_first_touch(spec: &RunSpec) -> Result<RunResult> {
    let mut w = spec.make_workload()?;
    let cap = Engine::fm_capacity(w.rss_pages(), spec.fm_fraction);
    let mut ft = FirstTouch::new(cap);
    Ok(spec.engine().run(w.as_mut(), &mut ft, cap, |_| None))
}

/// Run under the MEMTIS-style dynamic-threshold policy.
pub fn run_memtis(spec: &RunSpec) -> Result<RunResult> {
    let mut w = spec.make_workload()?;
    let cap = Engine::fm_capacity(w.rss_pages(), spec.fm_fraction);
    let mut m = crate::tpp::Memtis::new(Watermarks::default_for_capacity(cap));
    Ok(spec.engine().run(w.as_mut(), &mut m, cap, |_| None))
}

/// Run under `tpp-nomad`: TPP's control loop with Nomad-style
/// transactional non-exclusive migration. A spec without an explicit
/// non-exclusive mode runs the policy's default transactional knobs.
pub fn run_tpp_nomad(spec: &RunSpec) -> Result<RunResult> {
    let mut w = spec.make_workload()?;
    let cap = Engine::fm_capacity(w.rss_pages(), spec.fm_fraction);
    let mut p = TppNomad::with_hot_thr(Watermarks::default_for_capacity(cap), spec.hot_thr);
    p.set_scan_budget(spec.machine.promote_scan_pages_per_interval);
    if let m @ MigrationModel::NonExclusive { .. } = spec.migration {
        p = p.with_migration(m);
    }
    Ok(spec.engine().run(w.as_mut(), &mut p, cap, |_| None))
}

/// Run under `tpp-gated`: TPP's control loop behind the migration
/// admission gate. A spec whose [`RunSpec::admission`] is disabled runs
/// the enabled default gate (gating is the policy's identity — run
/// [`run_tpp`] for ungated TPP); an enabled spec's knobs are used as-is.
pub fn run_tpp_gated(spec: &RunSpec) -> Result<RunResult> {
    let mut w = spec.make_workload()?;
    let cap = Engine::fm_capacity(w.rss_pages(), spec.fm_fraction);
    let mut p = TppGated::with_hot_thr(Watermarks::default_for_capacity(cap), spec.hot_thr)
        .with_admission(spec.admission);
    p.set_scan_budget(spec.machine.promote_scan_pages_per_interval);
    Ok(spec.engine().run(w.as_mut(), &mut p, cap, |_| None))
}

/// The fast-memory-only baseline: 100% of RSS in fast memory. Always
/// exclusive and ungated — at 100% fast there is nothing to migrate (or
/// to admit), and forcing both modes off keeps one cached baseline valid
/// for every migration-mode and admission cell (the baseline cache is
/// keyed without either axis).
pub fn run_fm_only(spec: &RunSpec) -> Result<RunResult> {
    run_tpp(
        &spec
            .clone()
            .with_fraction(1.0)
            .with_migration(MigrationModel::Exclusive)
            .with_admission(AdmissionConfig::default()),
    )
}

/// Run under TPP while profiling: returns the run plus the telemetry
/// configuration vector aggregated over the whole run (what §6.1 does to
/// build the query for the model-accuracy study).
pub fn profile_tpp(
    spec: &RunSpec,
) -> Result<(RunResult, crate::microbench::MicrobenchConfig)> {
    let mut w = spec.make_workload()?;
    let cap = Engine::fm_capacity(w.rss_pages(), spec.fm_fraction);
    let mut tpp = Tpp::with_hot_thr(Watermarks::default_for_capacity(cap), spec.hot_thr)
        .with_admission(spec.admission);
    tpp.scan_budget = spec.machine.promote_scan_pages_per_interval;
    let mut window = crate::telemetry::WindowAggregator::new(
        spec.hot_thr,
        w.threads(),
        w.rss_pages() as u64,
    );
    let result = spec.engine().run(w.as_mut(), &mut tpp, cap, |t| {
        // skip the allocation epoch: its burst is not steady-state
        if t.interval > 1 {
            window.observe(&t.sample());
        }
        None
    });
    let cfg = window
        .take_window_config()
        .ok_or_else(|| anyhow!("empty telemetry window"))?;
    Ok((result, cfg))
}

/// Result of a Tuna-managed run.
pub struct TunaRun {
    pub result: RunResult,
    pub decisions: Vec<Decision>,
    /// Mean / minimum fast-memory fraction across decisions.
    pub mean_fraction: f64,
    pub min_fraction: f64,
    /// Cumulative vmstat counters at end of run.
    pub vmstat: Vec<(&'static str, u64)>,
    /// Total query-path time (ns) across all decisions.
    pub decide_ns: u128,
    /// Query backend used ("native" or "xla").
    pub backend: &'static str,
    /// Predicted-vs-realized outcomes (empty unless the run's
    /// `cfg.retune` mode is `observe` or `on`).
    pub outcomes: Vec<OutcomeRecord>,
    /// Drift-forced early re-decides taken (0 unless `retune = on`).
    pub retunes: u64,
}

impl TunaRun {
    /// Fast-memory saving: `1 − mean_fraction` (what Figs. 3–7 plot; the
    /// saving is reported against the workload's peak RSS, §6).
    pub fn mean_saving(&self) -> f64 {
        1.0 - self.mean_fraction
    }

    pub fn max_saving(&self) -> f64 {
        1.0 - self.min_fraction
    }
}

/// Run under TPP + Tuna with the given performance database and query
/// backend. The run starts at 100% fast memory (the paper's deployment
/// scenario: shrink from peak).
///
/// Since the tuner-as-a-service redesign this is a thin wrapper: it
/// stands up a private synchronous [`TunerService`] and runs one session
/// against it. Use [`run_tuna_service`] to share one (possibly
/// channel-mode) service across many runs, or [`run_tuna_inloop`] for
/// the classic in-loop tuner the service is proven bit-identical to.
pub fn run_tuna(
    spec: &RunSpec,
    db: Arc<dyn PerfSource>,
    query: Box<dyn NnQuery + Send>,
    tuna: &TunaConfig,
) -> Result<TunaRun> {
    let service = TunerService::inline_with_obs(db, query, spec.obs.clone());
    run_tuna_service(spec, &service, tuna)
}

/// Convenience: Tuna with the native (brute-force) query backend.
pub fn run_tuna_native(spec: &RunSpec, db: Arc<PerfDb>, tuna: &TunaConfig) -> Result<TunaRun> {
    let query = Box::new(NativeNn::new(&db));
    run_tuna(spec, db, query, tuna)
}

/// Run one Tuna-managed session against a caller-owned [`TunerService`]
/// (shared by any number of concurrent runs — this is the path sweep
/// Tuna cells take). The engine publishes a [`crate::telemetry::TelemetrySample`]
/// per interval; watermark decisions come back through the session
/// mailbox at period boundaries.
pub fn run_tuna_service(
    spec: &RunSpec,
    service: &TunerService,
    tuna: &TunaConfig,
) -> Result<TunaRun> {
    run_tuna_session(spec, service, tuna, None)
}

/// As [`run_tuna_service`], additionally passing every stream event
/// (open / one sample per interval / close) to `tap` — the recording
/// hook behind `tuna tune --record`, whose output `tuna serve` replays
/// to the same decisions.
pub fn run_tuna_service_tapped(
    spec: &RunSpec,
    service: &TunerService,
    tuna: &TunaConfig,
    mut tap: impl FnMut(&Event),
) -> Result<TunaRun> {
    run_tuna_session(spec, service, tuna, Some(&mut tap))
}

/// Shared body: the event construction (with its per-interval name
/// clone) happens only when a tap is attached, so the common untapped
/// path — every sweep Tuna cell — publishes samples allocation-free.
fn run_tuna_session(
    spec: &RunSpec,
    service: &TunerService,
    tuna: &TunaConfig,
    mut tap: Option<&mut dyn FnMut(&Event)>,
) -> Result<TunaRun> {
    let mut w = spec.make_workload()?;
    let rss = w.rss_pages() as u64;
    let cap = Engine::fm_capacity(w.rss_pages(), 1.0);
    let mut tpp = Tpp::with_hot_thr(Watermarks::default_for_capacity(cap), spec.hot_thr)
        .with_admission(spec.admission);
    tpp.scan_budget = spec.machine.promote_scan_pages_per_interval;
    let session_spec = SessionSpec {
        name: format!("{}@{}", spec.workload.to_ascii_lowercase(), spec.seed),
        capacity: cap,
        rss_pages: rss,
        hot_thr: spec.hot_thr,
        threads: w.threads(),
        cfg: tuna.clone(),
    };
    if let Some(tap) = tap.as_mut() {
        tap(&Event::open_for(&session_spec));
    }
    let name = session_spec.name.clone();
    let mut session = service.register(session_spec)?;
    let result = spec.engine().run(w.as_mut(), &mut tpp, cap, |t| {
        let sample = t.sample();
        if let Some(tap) = tap.as_mut() {
            tap(&Event::Sample { name: name.clone(), sample });
        }
        session.publish(sample)
    });
    if let Some(tap) = tap.as_mut() {
        tap(&Event::Close { name });
    }
    let report = session.finish()?;
    Ok(TunaRun {
        result,
        decisions: report.decisions,
        mean_fraction: report.mean_fraction,
        min_fraction: report.min_fraction,
        vmstat: report.vmstat,
        decide_ns: report.decide_ns,
        backend: service.backend(),
        outcomes: report.outcomes,
        retunes: report.retunes,
    })
}

/// The pre-service in-loop path: a [`Tuner`] owning its query backend,
/// attached directly as the engine observer. Kept as the reference
/// implementation the service modes are proven bit-identical against
/// (see the integration suite's determinism tests).
pub fn run_tuna_inloop(
    spec: &RunSpec,
    db: Arc<dyn PerfSource>,
    query: Box<dyn NnQuery>,
    tuna: &TunaConfig,
) -> Result<TunaRun> {
    let mut w = spec.make_workload()?;
    let rss = w.rss_pages() as u64;
    let cap = Engine::fm_capacity(w.rss_pages(), 1.0);
    let mut tpp = Tpp::with_hot_thr(Watermarks::default_for_capacity(cap), spec.hot_thr)
        .with_admission(spec.admission);
    tpp.scan_budget = spec.machine.promote_scan_pages_per_interval;
    let backend = query.backend();
    let mut tuner = Tuner::new(
        db,
        query,
        tuna.clone(),
        cap,
        rss,
        spec.hot_thr,
        w.threads(),
    );
    tuner.set_obs(spec.obs.clone());
    tuner
        .state
        .set_session_label(&format!("{}@{}", spec.workload.to_ascii_lowercase(), spec.seed));
    let result = spec.engine().run(w.as_mut(), &mut tpp, cap, |t| tuner.observe(t));
    // settle the last decision's outcome window (same close semantics
    // as the service path)
    let end_interval = result.trace.last().map(|t| t.interval).unwrap_or(0);
    tuner.finish_outcome(end_interval);
    Ok(TunaRun {
        result,
        mean_fraction: tuner.mean_fraction(),
        min_fraction: tuner.min_fraction(),
        vmstat: tuner.vmstat(),
        decide_ns: tuner.decide_ns(),
        outcomes: tuner.state.outcomes().to_vec(),
        retunes: tuner.state.retunes(),
        decisions: std::mem::take(&mut tuner.state.decisions),
        backend,
    })
}

/// Per-period relative loss series: windows of `period` intervals,
/// loss = (T_window − T_base_window) / T_base_window. Skips the
/// allocation epoch (interval 1) which is identical in both runs.
/// Windows with a degenerate (zero-time) baseline report 0.0 loss.
pub fn period_loss_series(run: &RunResult, baseline: &RunResult, period: u32) -> Vec<f64> {
    let n = run.trace.len().min(baseline.trace.len());
    let mut out = Vec::new();
    let mut i = 1; // skip allocation epoch
    while i + (period as usize) <= n {
        let t: f64 = run.trace[i..i + period as usize].iter().map(|x| x.wall_ns).sum();
        let b: f64 = baseline.trace[i..i + period as usize]
            .iter()
            .map(|x| x.wall_ns)
            .sum();
        out.push(if b > 0.0 { (t - b) / b } else { 0.0 });
        i += period as usize;
    }
    out
}

/// Usable-fast-memory fraction series (per interval), relative to RSS.
pub fn fm_fraction_series(run: &RunResult, rss_pages: u64) -> Vec<f64> {
    run.trace
        .iter()
        .map(|t| (t.usable_fm.min(rss_pages)) as f64 / rss_pages as f64)
        .collect()
}

/// Overall loss of `run` vs `baseline`, excluding the allocation epoch.
/// A degenerate (zero-time or empty) baseline yields 0.0 rather than
/// `NaN`/`inf`, matching [`RunResult::perf_loss_vs`]; a non-finite *run*
/// time still propagates so broken measurements surface.
pub fn overall_loss(run: &RunResult, baseline: &RunResult) -> f64 {
    let t: f64 = run.trace.iter().skip(1).map(|x| x.wall_ns).sum();
    let b: f64 = baseline.trace.iter().skip(1).map(|x| x.wall_ns).sum();
    if !(b > 0.0) || !b.is_finite() {
        return 0.0;
    }
    (t - b) / b
}

/// `tuna whatif` measured mode: the overall loss of running the spec's
/// workload under TPP at [`RunSpec::fm_fraction`], against its own
/// fast-memory-only baseline. This is exactly the composition a sweep
/// cell records for the same (workload, fraction) — same runs, same
/// [`overall_loss`] — so the answer agrees bit-for-bit with the
/// offline sweep table (proven in the integration suite).
pub fn whatif_measured(spec: &RunSpec) -> Result<f64> {
    let run = run_tpp(spec)?;
    let baseline = run_fm_only(spec)?;
    Ok(overall_loss(&run, &baseline))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::perfdb::builder::{build_database, BuildParams};

    fn small_spec(workload: &str) -> RunSpec {
        let mut s = RunSpec::new(workload);
        s.intervals = 60;
        s
    }

    fn small_db() -> Arc<PerfDb> {
        let params = BuildParams {
            n_configs: 40,
            fractions: vec![1.0, 0.95, 0.9, 0.85, 0.8, 0.7, 0.6, 0.5],
            intervals: 4,
            warmup: 2,
            seed: 11,
            machine: MachineModel::default(),
            threads: 4,
        };
        Arc::new(build_database(&params))
    }

    #[test]
    fn fm_only_baseline_has_no_slow_accesses_after_warmup() {
        let res = run_fm_only(&small_spec("Btree")).unwrap();
        let slow: u64 = res.trace.iter().skip(2).map(|t| t.acc_slow).sum();
        let total: u64 = res.trace.iter().skip(2).map(|t| t.acc_fast + t.acc_slow).sum();
        assert!(
            (slow as f64) < 0.01 * total as f64,
            "slow {slow}/{total} at 100% fast memory"
        );
    }

    #[test]
    fn loss_grows_as_fm_shrinks() {
        let base = run_fm_only(&small_spec("BFS")).unwrap();
        let l90 = overall_loss(&run_tpp(&small_spec("BFS").with_fraction(0.9)).unwrap(), &base);
        let l40 = overall_loss(&run_tpp(&small_spec("BFS").with_fraction(0.4)).unwrap(), &base);
        assert!(l90 >= -0.01, "l90={l90}");
        assert!(l40 > l90, "l40={l40} l90={l90}");
    }

    #[test]
    fn tuna_run_produces_decisions_and_savings() {
        let db = small_db();
        let spec = small_spec("Btree");
        let tuna = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
        let run = run_tuna_native(&spec, db, &tuna).unwrap();
        assert!(!run.decisions.is_empty());
        assert!(run.mean_fraction <= 1.0);
        assert!(run.max_saving() >= run.mean_saving());
        assert_eq!(run.backend, "native");
        // decisions happen once per period (10 intervals at 1.0 s)
        let expected = (spec.intervals - 1) / 10;
        assert!(
            (run.decisions.len() as i64 - expected as i64).abs() <= 2,
            "decisions={} expected≈{expected}",
            run.decisions.len()
        );
    }

    #[test]
    fn tuna_observe_mode_matches_off_and_reports_outcomes() {
        use crate::outcome::{RetuneConfig, RetuneMode};
        let db = small_db();
        let spec = small_spec("Btree");
        let tuna_off = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
        let mut tuna_observe = tuna_off.clone();
        tuna_observe.retune =
            RetuneConfig { mode: RetuneMode::Observe, ..RetuneConfig::default() };
        let off = run_tuna_native(&spec, db.clone(), &tuna_off).unwrap();
        let observed = run_tuna_native(&spec, db, &tuna_observe).unwrap();
        assert_eq!(
            off.result.total_ns.to_bits(),
            observed.result.total_ns.to_bits(),
            "observe mode must not perturb the run"
        );
        assert_eq!(off.decisions.len(), observed.decisions.len());
        for (a, b) in off.decisions.iter().zip(&observed.decisions) {
            assert_eq!(a.interval, b.interval);
            assert_eq!(a.fraction.to_bits(), b.fraction.to_bits());
        }
        assert!(off.outcomes.is_empty(), "off mode reports no outcomes");
        assert_eq!(off.retunes, 0);
        // every decision is accounted except (at most) a trailing one
        // whose window saw no further samples
        assert!(
            observed.outcomes.len() + 1 >= observed.decisions.len()
                && !observed.outcomes.is_empty(),
            "outcomes {} for {} decisions",
            observed.outcomes.len(),
            observed.decisions.len()
        );
        assert_eq!(observed.retunes, 0, "observe mode never acts");
    }

    #[test]
    fn period_series_have_expected_length() {
        let base = run_fm_only(&small_spec("XSBench")).unwrap();
        let run = run_tpp(&small_spec("XSBench").with_fraction(0.9)).unwrap();
        let series = period_loss_series(&run, &base, 10);
        assert_eq!(series.len(), (60 - 1) / 10);
        let fm = fm_fraction_series(&run, 1_000_000);
        assert_eq!(fm.len(), run.trace.len());
    }

    #[test]
    fn overall_loss_guards_empty_baseline() {
        let run = run_tpp(&small_spec("Btree").with_fraction(0.9)).unwrap();
        let empty = RunResult {
            workload: "none",
            policy: "tpp",
            fast_capacity: 0,
            total_ns: 0.0,
            trace: vec![],
        };
        assert_eq!(overall_loss(&run, &empty), 0.0, "empty baseline must not yield inf");
        assert_eq!(overall_loss(&empty, &empty), 0.0, "0/0 must not yield NaN");
        assert!(period_loss_series(&run, &empty, 10).is_empty());
    }

    #[test]
    fn memtis_policy_runs_and_migrates() {
        let res = run_memtis(&small_spec("Btree").with_fraction(0.8)).unwrap();
        assert_eq!(res.policy, "memtis");
        assert!(res.total_promoted() > 0);
    }

    #[test]
    fn unknown_workload_is_an_error() {
        assert!(run_tpp(&RunSpec::new("nope")).is_err());
    }

    #[test]
    fn tpp_nomad_run_exercises_transactional_counters() {
        let res = run_tpp_nomad(&small_spec("kv-drift").with_fraction(0.6)).unwrap();
        assert_eq!(res.policy, "tpp-nomad");
        let c = res.total_migration_counters();
        assert!(
            c.shadow_hits + c.shadow_free_demotions + c.txn_aborts > 0,
            "non-exclusive kv-drift run must show shadow/txn activity: {c:?}"
        );
    }

    #[test]
    fn migration_spec_threads_through_stock_policies() {
        // the same TPP run under exclusive vs non-exclusive semantics
        let spec = small_spec("kv-drift").with_fraction(0.6);
        let excl = run_tpp(&spec).unwrap();
        let c = excl.total_migration_counters();
        assert_eq!(
            (c.shadow_hits, c.shadow_free_demotions, c.txn_aborts, c.txn_retried_copies),
            (0, 0, 0, 0),
            "exclusive runs must report zero shadow/txn counters"
        );
        let nonexcl =
            run_tpp(&spec.clone().with_migration(MigrationModel::non_exclusive_default()))
                .unwrap();
        let n = nonexcl.total_migration_counters();
        assert!(
            n.shadow_hits + n.shadow_free_demotions + n.txn_aborts > 0,
            "spec-level migration mode must reach the engine: {n:?}"
        );
    }

    #[test]
    fn fm_only_baseline_is_identical_across_migration_modes() {
        let spec = small_spec("Btree");
        let a = run_fm_only(&spec).unwrap();
        let b = run_fm_only(&spec.clone().with_migration(MigrationModel::non_exclusive_default()))
            .unwrap();
        assert_eq!(a.total_ns.to_bits(), b.total_ns.to_bits());
    }

    #[test]
    fn admission_spec_threads_through_run_tpp() {
        // disabled spec: no gate, zero verdicts, bit-identical to a spec
        // that never heard of admission (the constructor default)
        let spec = small_spec("kv-drift").with_fraction(0.6);
        let plain = run_tpp(&spec).unwrap();
        assert_eq!(plain.total_admission_verdicts(), 0, "disabled spec must install no gate");

        // enabled spec: the gate reaches the policy and records verdicts
        let gated = run_tpp(
            &spec.clone().with_admission(AdmissionConfig::enabled_default()),
        )
        .unwrap();
        assert!(
            gated.total_admission_verdicts() > 0,
            "spec-level admission must reach the policy"
        );
        assert_eq!(
            gated.total_admission_accepted(),
            gated.total_promoted() + gated.total_promote_failed(),
            "every accepted candidate must reach the promotion path"
        );
    }

    #[test]
    fn run_tpp_gated_defaults_to_the_enabled_gate() {
        // a spec with the admission default (disabled) still runs gated —
        // gating is tpp-gated's identity, mirroring run_tpp_nomad's
        // non-exclusive clamp
        let res = run_tpp_gated(&small_spec("kv-drift").with_fraction(0.6)).unwrap();
        assert_eq!(res.policy, "tpp-gated");
        assert!(res.total_admission_verdicts() > 0, "gated run must record verdicts");
    }

    #[test]
    fn fm_only_baseline_is_identical_across_admission_settings() {
        let spec = small_spec("Btree");
        let a = run_fm_only(&spec).unwrap();
        let b = run_fm_only(&spec.clone().with_admission(AdmissionConfig::enabled_default()))
            .unwrap();
        assert_eq!(a.total_ns.to_bits(), b.total_ns.to_bits());
    }
}
