//! Observability overhead: the same tuned engine run and the same
//! service ingest load, measured with the recorder disabled, with the
//! metrics/ring hot path enabled, and with the journal + metrics
//! additionally persisted to disk at the end.
//!
//! The disabled row is the PR 7 contract: `Recorder::default()` must
//! cost nothing on the hot path (a branch on an `Option` that is
//! `None`). The ring row prices the per-interval/per-decision metric
//! and event recording; the journal row adds the one-shot `TUNAOBS1`
//! encode + atomic write at shutdown.

use std::sync::Arc;
use std::time::Instant;

use tuna::config::experiment::TunaConfig;
use tuna::coordinator::{self, RunSpec};
use tuna::obs::{Recorder, DEFAULT_RING_CAPACITY};
use tuna::outcome::{RetuneConfig, RetuneMode};
use tuna::perfdb::builder::{build_database, BuildParams};
use tuna::perfdb::native::NativeNn;
use tuna::perfdb::PerfDb;
use tuna::report::{results_dir, Table};
use tuna::service::{SessionReport, SessionSpec, TunerService};
use tuna::sim::MachineModel;
use tuna::telemetry::TelemetrySample;
use tuna::util::human_ns;

const ENGINE_INTERVALS: u32 = 400;
const ENGINE_REPS: u32 = 3;
const INGEST_SESSIONS: usize = 8;
const SAMPLES_PER_SESSION: u32 = 5_000;

#[derive(Clone, Copy)]
enum Mode {
    Disabled,
    Ring,
    RingAndJournal,
}

impl Mode {
    fn name(self) -> &'static str {
        match self {
            Mode::Disabled => "disabled",
            Mode::Ring => "ring-only",
            Mode::RingAndJournal => "ring+journal",
        }
    }

    fn recorder(self) -> Recorder {
        match self {
            Mode::Disabled => Recorder::disabled(),
            _ => Recorder::enabled(DEFAULT_RING_CAPACITY),
        }
    }
}

const MODES: [Mode; 3] = [Mode::Disabled, Mode::Ring, Mode::RingAndJournal];

fn flush(mode: Mode, obs: &Recorder, tag: &str) -> tuna::Result<()> {
    if let Mode::RingAndJournal = mode {
        let dir = results_dir();
        obs.write_journal(&dir.join(format!("obs_overhead_{tag}.journal.bin")))?;
        obs.write_metrics(&dir.join(format!("obs_overhead_{tag}.prom")))?;
    }
    Ok(())
}

/// Full tuned runs (engine + in-loop service + tuner), intervals/sec.
fn bench_engine(db: &Arc<PerfDb>, t: &mut Table) -> tuna::Result<()> {
    let cfg = TunaConfig { period_s: 1.0, ..TunaConfig::default() };
    for mode in MODES {
        let obs = mode.recorder();
        let t0 = Instant::now();
        let mut decisions = 0usize;
        for rep in 0..ENGINE_REPS {
            let spec = RunSpec::new("Btree")
                .with_intervals(ENGINE_INTERVALS)
                .with_seed(7 + rep as u64)
                .with_obs(obs.clone());
            let run = coordinator::run_tuna_native(&spec, db.clone(), &cfg)?;
            decisions += run.decisions.len();
            std::hint::black_box(&run.result.total_ns);
        }
        flush(mode, &obs, "engine")?;
        let wall_ns = t0.elapsed().as_nanos() as f64;
        let intervals = (ENGINE_INTERVALS * ENGINE_REPS) as f64;
        t.row(vec![
            "tuned run".to_string(),
            mode.name().to_string(),
            format!("{intervals} intervals, {decisions} decisions"),
            human_ns(wall_ns as u64),
            format!("{:.0} intervals/s", intervals / (wall_ns / 1e9)),
            human_ns((wall_ns / intervals) as u64),
        ]);
    }
    Ok(())
}

/// The same tuned engine load as `bench_engine`, with the ring recorder
/// on throughout, sweeping the outcome tracker's retune mode: `off`
/// (the tracker is never constructed work), `observe` (per-sample
/// accumulation + outcome joins + drift EWMA, never acting) and `on`
/// (the full loop including forced early re-decides). The off row is
/// the PR 9 contract: an off-mode tracker must be free on the ingest
/// hot path.
fn bench_outcome(db: &Arc<PerfDb>, t: &mut Table) -> tuna::Result<()> {
    for mode in [RetuneMode::Off, RetuneMode::Observe, RetuneMode::On] {
        let cfg = TunaConfig {
            period_s: 1.0,
            retune: RetuneConfig { mode, ..RetuneConfig::default() },
            ..TunaConfig::default()
        };
        let obs = Recorder::enabled(DEFAULT_RING_CAPACITY);
        let t0 = Instant::now();
        let mut decisions = 0usize;
        let mut outcomes = 0usize;
        for rep in 0..ENGINE_REPS {
            let spec = RunSpec::new("Btree")
                .with_intervals(ENGINE_INTERVALS)
                .with_seed(7 + rep as u64)
                .with_obs(obs.clone());
            let run = coordinator::run_tuna_native(&spec, db.clone(), &cfg)?;
            decisions += run.decisions.len();
            outcomes += run.outcomes.len();
            std::hint::black_box(&run.result.total_ns);
        }
        let wall_ns = t0.elapsed().as_nanos() as f64;
        let intervals = (ENGINE_INTERVALS * ENGINE_REPS) as f64;
        t.row(vec![
            "outcome tracker".to_string(),
            format!("retune {}", mode.name()),
            format!("{intervals} intervals, {decisions} decisions, {outcomes} outcomes"),
            human_ns(wall_ns as u64),
            format!("{:.0} intervals/s", intervals / (wall_ns / 1e9)),
            human_ns((wall_ns / intervals) as u64),
        ]);
    }
    Ok(())
}

fn session_spec(name: String) -> SessionSpec {
    SessionSpec {
        name,
        capacity: 9_000,
        rss_pages: 8_000,
        hot_thr: 2,
        threads: 16,
        cfg: TunaConfig::default(),
    }
}

fn synth_sample(interval: u32, salt: u64) -> TelemetrySample {
    TelemetrySample {
        interval,
        acc_fast: 9_000 + salt % 512,
        acc_slow: 700,
        sacc_fast: 9_000 + salt % 512,
        sacc_slow: 700,
        flops: 500_000,
        iops: 500_000,
        promoted: 25,
        promote_failed: 1,
        demoted_kswapd: 22,
        demoted_direct: 3,
        shadow_hits: salt % 64,
        shadow_free_demotions: 5,
        txn_aborts: 2,
        txn_retried_copies: 1,
        admission_accepted: 20,
        admission_rejected_budget: 2,
        admission_rejected_payoff: 3,
        admission_rejected_cooldown: salt % 8,
        fast_free: 180,
        wall_ns: 1_000_000 + salt % 4_096,
    }
}

/// Concurrent telemetry publishers over the channel service, samples/sec.
fn bench_ingest(db: &Arc<PerfDb>, t: &mut Table) -> tuna::Result<()> {
    for mode in MODES {
        let obs = mode.recorder();
        let service = TunerService::spawn_with_obs(
            db.clone(),
            Box::new(NativeNn::new(db)),
            obs.clone(),
        );
        let t0 = Instant::now();
        let reports: Vec<SessionReport> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..INGEST_SESSIONS)
                .map(|i| {
                    let service = &service;
                    s.spawn(move || {
                        let mut h = service
                            .register(session_spec(format!("obs-bench-{i}")))
                            .expect("register session");
                        for k in 1..=SAMPLES_PER_SESSION {
                            std::hint::black_box(h.publish(synth_sample(k, i as u64)));
                        }
                        h.finish().expect("session report")
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("publisher thread")).collect()
        });
        flush(mode, &obs, "ingest")?;
        let wall_ns = t0.elapsed().as_nanos() as f64;
        service.shutdown();
        let samples: u64 = reports.iter().map(|r| r.samples).sum();
        let decisions: usize = reports.iter().map(|r| r.decisions.len()).sum();
        t.row(vec![
            "service ingest".to_string(),
            mode.name().to_string(),
            format!("{samples} samples, {decisions} decisions"),
            human_ns(wall_ns as u64),
            format!("{:.0} samples/s", samples as f64 / (wall_ns / 1e9)),
            human_ns((wall_ns / samples as f64) as u64),
        ]);
    }
    Ok(())
}

fn main() -> tuna::Result<()> {
    let db = Arc::new(build_database(&BuildParams {
        n_configs: 64,
        fractions: vec![1.0, 0.9, 0.8, 0.7, 0.6, 0.5],
        intervals: 3,
        warmup: 1,
        seed: 17,
        machine: MachineModel::default(),
        threads: 4,
    }));

    let mut t = Table::new(
        "observability overhead: identical load, recorder off / ring / ring+journal",
        &["path", "obs", "work", "wall", "throughput", "per-unit"],
    );
    bench_engine(&db, &mut t)?;
    bench_outcome(&db, &mut t)?;
    bench_ingest(&db, &mut t)?;
    t.print();
    t.to_csv(&results_dir().join("obs_overhead.csv"))?;
    Ok(())
}
