//! Ablations over the design choices DESIGN.md calls out:
//!
//! (a) per-page serialization term on/off — the asymmetry that produces
//!     Table 2's "error grows as FM shrinks" trend;
//! (b) kswapd reclaim budget — the Fig. 1 cliff's failure dynamics;
//! (c) TPP promotion threshold `hot_thr`;
//! (d) k-NN averaging vs 1-NN on the query side;
//! (e) policy family: TPP (fixed hot_thr) vs MEMTIS (dynamic hot_thr)
//!     vs first-touch under the same fast-memory pressure;
//! (f) migration model: the engine-side cost of the Nomad-style
//!     transactional machinery (shadow tracking, in-flight copies) —
//!     exclusive and non-exclusive runs should stay within a few
//!     percent of each other in simulation throughput;
//! (g) migration admission control: gated vs ungated TPP on the
//!     drifting-hot-set workload — the gate's budget/payoff/cool-down
//!     filters should cut migration traffic (and its loss) where
//!     ping-pong promotion is the failure mode.

use std::path::Path;
use std::time::Instant;

use tuna::coordinator::{self, RunSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::perfdb::native::NativeNn;
use tuna::perfdb::normalize;
use tuna::report::{pct, results_dir, Table};
use tuna::sim::{Engine, IntervalModel, MachineModel, MigrationModel};
use tuna::tpp::{Tpp, Watermarks};
use tuna::util::human_ns;
use tuna::workloads;

fn main() -> tuna::Result<()> {
    // --- (a) serialization term ---
    let mut t_a = Table::new(
        "(a) per-page serialization term (BFS @ 85% FM)",
        &["model", "loss vs fast-only"],
    );
    for serialization in [true, false] {
        let run_with = |fraction: f64| {
            let mut w = workloads::by_name("BFS", 42, 200).unwrap();
            let cap = Engine::fm_capacity(w.rss_pages(), fraction);
            let mut tpp = Tpp::new(Watermarks::default_for_capacity(cap));
            let mut model = IntervalModel::new(MachineModel::default());
            model.serialization = serialization;
            Engine::new(model).run(w.as_mut(), &mut tpp, cap, |_| None)
        };
        let base = run_with(1.0);
        let small = run_with(0.85);
        t_a.row(vec![
            if serialization { "with serialization".into() } else { "without (microbench-optimistic)".into() },
            pct(coordinator::overall_loss(&small, &base)),
        ]);
    }
    t_a.print();
    t_a.to_csv(&results_dir().join("ablation_serialization.csv"))?;

    // --- (b) kswapd budget ---
    let mut t_b = Table::new(
        "(b) kswapd reclaim budget (BFS @ 70% FM)",
        &["pages/interval", "loss", "promotions", "failures"],
    );
    for budget in [8u64, 32, 128, 512] {
        let machine =
            MachineModel { kswapd_pages_per_interval: budget, ..MachineModel::default() };
        let mut spec = RunSpec::new("BFS").with_intervals(200).with_fraction(0.7);
        spec.machine = machine.clone();
        let base_spec = spec.clone().with_fraction(1.0);
        let base = coordinator::run_tpp(&base_spec)?;
        let run = coordinator::run_tpp(&spec)?;
        t_b.row(vec![
            budget.to_string(),
            pct(coordinator::overall_loss(&run, &base)),
            run.total_promoted().to_string(),
            run.total_promote_failed().to_string(),
        ]);
    }
    t_b.print();
    t_b.to_csv(&results_dir().join("ablation_kswapd.csv"))?;

    // --- (c) hot_thr ---
    let mut t_c = Table::new(
        "(c) TPP promotion threshold (SSSP @ 85% FM)",
        &["hot_thr", "loss", "promotions", "failures"],
    );
    for hot_thr in [2u32, 4, 8] {
        let mut spec = RunSpec::new("SSSP").with_intervals(200).with_fraction(0.85);
        spec.hot_thr = hot_thr;
        let base = coordinator::run_tpp(&spec.clone().with_fraction(1.0))?;
        let run = coordinator::run_tpp(&spec)?;
        t_c.row(vec![
            hot_thr.to_string(),
            pct(coordinator::overall_loss(&run, &base)),
            run.total_promoted().to_string(),
            run.total_promote_failed().to_string(),
        ]);
    }
    t_c.print();
    t_c.to_csv(&results_dir().join("ablation_hot_thr.csv"))?;

    // --- (d) 1-NN vs k-NN averaging ---
    let db = ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?;
    let nn = NativeNn::new(&db);
    let mut t_d = Table::new(
        "(d) query: 1-NN vs k-NN-averaged predicted min fraction (BFS profile, τ=5%)",
        &["k", "predicted min FM fraction"],
    );
    let spec = RunSpec::new("BFS").with_intervals(150);
    let (_, cfg) = coordinator::profile_tpp(&spec)?;
    let q = normalize(&cfg.as_array());
    for k in [1usize, 3, 5] {
        let top = nn.top_k(&q, k);
        let frac = top
            .iter()
            .filter_map(|&(r, _)| db.min_fraction_within(r, 0.05))
            .sum::<f64>()
            / top.len() as f64;
        t_d.row(vec![k.to_string(), format!("{frac:.3}")]);
    }
    t_d.print();
    t_d.to_csv(&results_dir().join("ablation_knn.csv"))?;

    // --- (e) policy family under equal pressure ---
    let mut t_e = Table::new(
        "(e) page-management policy (Btree @ 80% FM)",
        &["policy", "loss", "promotions", "failures"],
    );
    let spec = RunSpec::new("Btree").with_intervals(200).with_fraction(0.8);
    let base = coordinator::run_fm_only(&spec)?;
    for (name, run) in [
        ("TPP", coordinator::run_tpp(&spec)?),
        ("MEMTIS (dynamic hot_thr)", coordinator::run_memtis(&spec)?),
        ("first-touch", coordinator::run_first_touch(&spec)?),
    ] {
        t_e.row(vec![
            name.to_string(),
            pct(coordinator::overall_loss(&run, &base)),
            run.total_promoted().to_string(),
            run.total_promote_failed().to_string(),
        ]);
    }
    t_e.print();
    t_e.to_csv(&results_dir().join("ablation_policy.csv"))?;

    // --- (f) migration model: cost of the transactional machinery ---
    let mut t_f = Table::new(
        "(f) migration model (kv-drift @ 60% FM): engine cost of transactional migration",
        &["model", "loss", "sim wall", "intervals/sec", "shadow hits", "txn aborts"],
    );
    let spec = RunSpec::new("kv-drift").with_intervals(200).with_fraction(0.6);
    let base = coordinator::run_fm_only(&spec)?;
    let mut walls = [0u64; 2];
    for (i, (name, migration)) in [
        ("exclusive", MigrationModel::Exclusive),
        ("non-exclusive", MigrationModel::non_exclusive_default()),
    ]
    .into_iter()
    .enumerate()
    {
        let spec = spec.clone().with_migration(migration);
        let t0 = Instant::now();
        let run = coordinator::run_tpp(&spec)?;
        let wall = t0.elapsed().as_nanos() as u64;
        walls[i] = wall;
        t_f.row(vec![
            name.to_string(),
            pct(coordinator::overall_loss(&run, &base)),
            human_ns(wall),
            format!("{:.0}", run.trace.len() as f64 / (wall as f64 / 1e9)),
            run.total_shadow_hits().to_string(),
            run.total_txn_aborts().to_string(),
        ]);
    }
    t_f.print();
    println!(
        "non-exclusive engine cost: {:+.1}% vs exclusive (transactional bookkeeping should stay within ~5%)",
        (walls[1] as f64 / walls[0] as f64 - 1.0) * 100.0
    );
    t_f.to_csv(&results_dir().join("ablation_migration.csv"))?;

    // --- (g) migration admission control: gated vs ungated drift ---
    let mut t_g = Table::new(
        "(g) admission control (kv-drift @ 60% FM): gated vs ungated promotion",
        &["policy", "loss", "promotions", "failures", "accepted", "rej budget", "rej payoff", "rej cooldown"],
    );
    let spec = RunSpec::new("kv-drift").with_intervals(200).with_fraction(0.6);
    let base = coordinator::run_fm_only(&spec)?;
    for (name, run) in [
        ("tpp (ungated)", coordinator::run_tpp(&spec)?),
        ("tpp-gated", coordinator::run_tpp_gated(&spec)?),
    ] {
        t_g.row(vec![
            name.to_string(),
            pct(coordinator::overall_loss(&run, &base)),
            run.total_promoted().to_string(),
            run.total_promote_failed().to_string(),
            run.total_admission_accepted().to_string(),
            run.total_admission_rejected_budget().to_string(),
            run.total_admission_rejected_payoff().to_string(),
            run.total_admission_rejected_cooldown().to_string(),
        ]);
    }
    t_g.print();
    t_g.to_csv(&results_dir().join("ablation_admission.csv"))?;
    Ok(())
}
