//! Figures 3–7: runtime fast-memory variance + per-period performance
//! loss for each of the five workloads under TPP + Tuna (τ = 5%, tuning
//! every 2.5 s).
//!
//! Paper anchors: overall losses XSBench 1.8%, BFS 2%, PageRank 4.6%,
//! SSSP 4.7%, Btree 4.6% — all within the 5% target; savings up to 16%
//! (Btree, Fig. 7); average saving 8.5%.

use std::path::Path;
use std::sync::Arc;

use tuna::config::experiment::TunaConfig;
use tuna::coordinator::{self, RunSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::report::{ascii_series, pct, results_dir, Table};
use tuna::workloads::{self, ALL_NAMES};

fn main() -> tuna::Result<()> {
    let db = Arc::new(ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?);
    let tuna_cfg = TunaConfig::default();
    let paper_loss = [("PageRank", 4.6), ("XSBench", 1.8), ("BFS", 2.0), ("SSSP", 4.7), ("Btree", 4.6)];

    let mut summary = Table::new(
        "Figs. 3–7 — Tuna runtime traces (τ = 5%, period 2.5 s)",
        &["Workload", "mean saving", "max saving", "overall loss", "paper loss", "decisions"],
    );
    let mut savings = Vec::new();
    for (fig, name) in ALL_NAMES.iter().enumerate() {
        let spec = RunSpec::new(name).with_intervals(500);
        let baseline = coordinator::run_fm_only(&spec)?;
        let run = coordinator::run_tuna_native(&spec, db.clone(), &tuna_cfg)?;
        let loss = coordinator::overall_loss(&run.result, &baseline);
        let rss = workloads::by_name(name, spec.seed, 1).unwrap().rss_pages() as u64;

        // series
        let fm = coordinator::fm_fraction_series(&run.result, rss);
        let xs: Vec<f64> = (0..fm.len()).map(|i| i as f64 * 0.1).collect();
        println!(
            "{}",
            ascii_series(
                &format!("Fig. {} — {name}: usable FM fraction (paper-s)", fig + 3),
                &xs,
                &fm,
                6
            )
        );
        let period = tuna_cfg.period_intervals();
        let loss_series = coordinator::period_loss_series(&run.result, &baseline, period);
        let lx: Vec<f64> = (0..loss_series.len()).map(|i| (i as f64 + 1.0) * 2.5).collect();
        println!(
            "{}",
            ascii_series(&format!("{name}: per-period loss"), &lx, &loss_series, 6)
        );

        // csv
        let mut csv = Table::new(
            &format!("fig{} {name} trace", fig + 3),
            &["paper_s", "fm_fraction", "period_loss"],
        );
        for (i, f) in fm.iter().enumerate() {
            let pl = loss_series.get(i / period as usize).copied().unwrap_or(f64::NAN);
            csv.row(vec![format!("{:.1}", i as f64 * 0.1), format!("{f:.4}"), format!("{pl:.4}")]);
        }
        csv.to_csv(&results_dir().join(format!("fig{}_{}.csv", fig + 3, name.to_lowercase())))?;

        let paper = paper_loss.iter().find(|p| p.0 == *name).unwrap().1;
        summary.row(vec![
            name.to_string(),
            pct(run.mean_saving()),
            pct(run.max_saving()),
            pct(loss),
            format!("{paper:.1}%"),
            run.decisions.len().to_string(),
        ]);
        savings.push(run.mean_saving());
    }
    summary.print();
    summary.to_csv(&results_dir().join("fig3_7_summary.csv"))?;
    let avg = savings.iter().sum::<f64>() / savings.len() as f64;
    println!("\naverage FM saving: {} (paper: 8.5%, Pond: 5%)", pct(avg));
    Ok(())
}
