//! KV trace pipeline throughput: generator ops/sec, trace
//! encode/decode MB/sec, and replay ops/sec + intervals/sec through the
//! full engine (TPP at 90% fast memory) for each generator family.
//!
//! This is the capacity number for trace-driven experiments: how fast a
//! recorded op stream turns back into engine intervals, end to end.

use std::time::Instant;

use tuna::coordinator::{self, RunSpec};
use tuna::report::{results_dir, Table};
use tuna::trace::{format, gen};
use tuna::util::human_ns;

const OP_INTERVALS: u32 = 60;

fn main() -> tuna::Result<()> {
    let mut t = Table::new(
        "KV trace pipeline: generate / encode / decode / replay",
        &[
            "family",
            "ops",
            "gen Mops/s",
            "enc MB/s",
            "dec MB/s",
            "replay ops/s",
            "intervals/s",
            "wall",
        ],
    );

    let dir = std::env::temp_dir().join(format!("tuna_trace_bench_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    for name in gen::FAMILY {
        let spec = gen::spec_by_name(name).expect("family spec");

        let t0 = Instant::now();
        let trace = gen::generate(&spec, 42, OP_INTERVALS);
        let gen_s = t0.elapsed().as_secs_f64();
        let ops = trace.total_ops();

        let t0 = Instant::now();
        let bytes = format::encode(&trace)?;
        let enc_s = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let decoded = format::decode(&bytes)?;
        let dec_s = t0.elapsed().as_secs_f64();
        assert_eq!(decoded, trace, "codec must round-trip");

        let path = dir.join(format!("{name}.trc"));
        format::save(&path, &trace)?;

        // full-engine replay: trace file → workload → TPP run
        let mut spec_run = RunSpec::new(&format!("trace:{}", path.display()));
        spec_run.intervals = OP_INTERVALS + 1;
        spec_run.fm_fraction = 0.9;
        let t0 = Instant::now();
        let run = coordinator::run_tpp(&spec_run)?;
        let replay_s = t0.elapsed().as_secs_f64();
        assert_eq!(run.trace.len(), OP_INTERVALS as usize + 1);

        let mb = bytes.len() as f64 / (1 << 20) as f64;
        t.row(vec![
            name.to_string(),
            ops.to_string(),
            format!("{:.1}", ops as f64 / gen_s / 1e6),
            format!("{:.0}", mb / enc_s),
            format!("{:.0}", mb / dec_s),
            format!("{:.0}", ops as f64 / replay_s),
            format!("{:.1}", run.trace.len() as f64 / replay_s),
            human_ns((replay_s * 1e9) as u64),
        ]);
    }

    t.print();
    t.to_csv(&results_dir().join("trace_replay.csv"))?;
    std::fs::remove_dir_all(&dir).ok();
    Ok(())
}
