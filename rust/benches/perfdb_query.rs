//! §5 implementation claims: performance-database query latency and
//! build/index time.
//!
//! Paper: 100 K records indexed in < 20 minutes (Faiss HNSW); a query
//! takes 500 µs. Here we time (a) DB construction throughput, (b) the
//! native brute-force query, (c) the AOT XLA query (the production path)
//! in both cached-device-buffer and re-upload-literal modes, and (d) the
//! batched-query executable. (b)–(d) also cross-check numerics.

use std::path::Path;
use std::sync::Arc;

use tuna::artifact::shard::{
    LazyShardedNn, LazyShardedPerfDb, ResidencyLimit, ShardedNn, ShardedPerfDb,
};
use tuna::perfdb::builder::{build_database, ensure_db, sample_config, BuildParams};
use tuna::perfdb::native::{NativeNn, NnQuery};
use tuna::perfdb::normalize;
use tuna::report::{results_dir, Table};
use tuna::runtime::{Manifest, PerfDbExec, XlaNn};
use tuna::util::bench::{time_it, time_once};
use tuna::util::human_ns;
use tuna::util::rng::Rng;

fn main() -> tuna::Result<()> {
    // --- (a) build throughput: serial vs cell-parallel ---
    // The builder parallelizes over n_configs × fractions cells with
    // byte-identical output for any thread count; time one build of each
    // and compare both wall time and bytes (the acceptance bar is ≥ 2×
    // on a 4-core machine).
    let mut small = BuildParams { n_configs: 64, ..BuildParams::default() };
    small.threads = 1;
    let mut serial_db = None;
    let t_serial_ns = time_once(|| serial_db = Some(build_database(&small)));
    small.threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut parallel_db = None;
    let t_parallel_ns = time_once(|| parallel_db = Some(build_database(&small)));
    assert_eq!(
        tuna::perfdb::store::to_bytes(&serial_db.unwrap()),
        tuna::perfdb::store::to_bytes(&parallel_db.unwrap()),
        "parallel build must be byte-identical to serial"
    );
    let speedup = t_serial_ns / t_parallel_ns;
    println!(
        "build ({} configs x {} sizes): serial {} -> {} threads {} ({speedup:.2}x, byte-identical)",
        small.n_configs,
        small.fractions.len(),
        human_ns(t_serial_ns as u64),
        small.threads,
        human_ns(t_parallel_ns as u64),
    );
    let per_record_ms = t_parallel_ns / 1e6 / small.n_configs as f64;
    let projected_100k_min = per_record_ms * 100_000.0 / 60_000.0;

    let db = ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?;
    let mut queries: Vec<[f32; 8]> = Vec::new();
    let mut rng = Rng::new(7);
    for _ in 0..64 {
        queries.push(normalize(&sample_config(&mut rng).as_array()));
    }

    let mut t = Table::new(
        "perf-DB query path (paper: 500 µs/query; index 100 K < 20 min)",
        &["path", "p50", "p95", "mean"],
    );

    // --- (b) native brute force ---
    let mut native = NativeNn::new(&db);
    let mut qi = 0usize;
    let tn = time_it(32, 256, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(native.nearest(q).unwrap());
    });
    t.row(vec![
        format!("native brute force ({} records)", db.len()),
        human_ns(tn.p50_ns() as u64),
        human_ns(tn.p95_ns() as u64),
        human_ns(tn.mean_ns() as u64),
    ]);

    // --- (b2) sharded query (artifact-store layout, 8 segments). At
    // this record count the query auto-selects the serial shard scan;
    // the parallel fan-out path is covered by the >8192-record test in
    // `artifact::shard`. ---
    let sharded = Arc::new(ShardedPerfDb::from_flat(&db, 8));
    let mut snn = ShardedNn::new(sharded, 0);
    let mut qi = 0usize;
    let ts = time_it(32, 256, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(snn.nearest(q).unwrap());
    });
    t.row(vec![
        "sharded (8 segments, serial scan at this size)".into(),
        human_ns(ts.p50_ns() as u64),
        human_ns(ts.p95_ns() as u64),
        human_ns(ts.mean_ns() as u64),
    ]);
    // numerics cross-check: sharded merge must equal the flat argmin
    {
        let mut native = NativeNn::new(&db);
        for q in &queries {
            let (si, sd) = snn.nearest(q)?;
            let (ni, nd) = native.nearest(q)?;
            assert_eq!((si, sd.to_bits()), (ni, nd.to_bits()), "sharded != native");
        }
        println!("numerics: sharded == native on {} queries ✓", queries.len());
    }

    // --- (b3) lazy residency: warm (all segments cached after first
    // touch) vs the eviction-churn worst case (cap 1 segment: every
    // query's fan-out reloads all 8 segments from disk). The gap between
    // the two rows is the price of serving a database N× larger than
    // resident memory; "warm" should sit at the (b2) sharded row.
    {
        let lazy_dir =
            std::env::temp_dir().join(format!("tuna_bench_lazy_{}", std::process::id()));
        std::fs::remove_dir_all(&lazy_dir).ok();
        ShardedPerfDb::from_flat(&db, 8).save(&lazy_dir)?;

        let warm_db = Arc::new(LazyShardedPerfDb::open(&lazy_dir, ResidencyLimit::UNBOUNDED)?);
        let mut warm = LazyShardedNn::new(warm_db.clone(), 0);
        let mut qi = 0usize;
        let tw = time_it(32, 256, || {
            let q = &queries[qi % queries.len()];
            qi += 1;
            std::hint::black_box(warm.nearest(q).unwrap());
        });
        t.row(vec![
            "lazy-sharded (warm: all 8 segments resident)".into(),
            human_ns(tw.p50_ns() as u64),
            human_ns(tw.p95_ns() as u64),
            human_ns(tw.mean_ns() as u64),
        ]);

        let churn_db =
            Arc::new(LazyShardedPerfDb::open(&lazy_dir, ResidencyLimit::segments(1))?);
        let mut churn = LazyShardedNn::new(churn_db.clone(), 1);
        let mut qi = 0usize;
        let tc = time_it(8, 64, || {
            let q = &queries[qi % queries.len()];
            qi += 1;
            std::hint::black_box(churn.nearest(q).unwrap());
        });
        t.row(vec![
            "lazy-sharded (churn worst case: cap 1 of 8)".into(),
            human_ns(tc.p50_ns() as u64),
            human_ns(tc.p95_ns() as u64),
            human_ns(tc.mean_ns() as u64),
        ]);

        // numerics + residency accounting cross-check
        let mut native = NativeNn::new(&db);
        for q in &queries {
            let (wi, wd) = warm.nearest(q)?;
            let (ci, cd) = churn.nearest(q)?;
            let (ni, nd) = native.nearest(q)?;
            assert_eq!((wi, wd.to_bits()), (ni, nd.to_bits()), "lazy warm != native");
            assert_eq!((ci, cd.to_bits()), (ni, nd.to_bits()), "lazy churn != native");
        }
        let ws = warm_db.stats();
        assert_eq!(ws.loads, 8, "warm path must load each segment exactly once");
        let cs = churn_db.stats();
        assert_eq!(cs.peak_resident_segments, 1, "churn path must honor the cap");
        println!(
            "numerics: lazy == native on {} queries ✓ (warm: {} loads; churn: {} loads, \
             {} evictions, peak {} resident)",
            queries.len(),
            ws.loads,
            cs.loads,
            cs.evictions,
            cs.peak_resident_segments
        );
        std::fs::remove_dir_all(&lazy_dir).ok();
    }

    // --- (c) XLA single query, cached + literal modes ---
    if Path::new("artifacts/manifest.txt").exists() {
        let mut xla = XlaNn::from_manifest(Path::new("artifacts"), &db)?;
        let mut qi = 0usize;
        let tc = time_it(16, 128, || {
            let q = &queries[qi % queries.len()];
            qi += 1;
            std::hint::black_box(xla.nearest(q).unwrap());
        });
        t.row(vec![
            "xla (cached device buffer)".into(),
            human_ns(tc.p50_ns() as u64),
            human_ns(tc.p95_ns() as u64),
            human_ns(tc.mean_ns() as u64),
        ]);

        xla.exec_mut().set_cached(false);
        let mut qi = 0usize;
        let tl = time_it(16, 128, || {
            let q = &queries[qi % queries.len()];
            qi += 1;
            std::hint::black_box(xla.nearest(q).unwrap());
        });
        t.row(vec![
            "xla (literal re-upload — §Perf baseline)".into(),
            human_ns(tl.p50_ns() as u64),
            human_ns(tl.p95_ns() as u64),
            human_ns(tl.mean_ns() as u64),
        ]);
        xla.exec_mut().set_cached(true);

        // numerics cross-check
        let mut native = NativeNn::new(&db);
        for q in &queries {
            let (_, dx) = xla.nearest(q)?;
            let (_, dn) = native.nearest(q)?;
            assert!((dx - dn).abs() < 1e-4, "xla {dx} vs native {dn}");
        }
        println!("numerics: xla == native on {} queries ✓", queries.len());

        // --- (d) batched executable ---
        let manifest = Manifest::load(Path::new("artifacts"))?;
        let batched =
            PerfDbExec::load(&manifest.batched_path(), &db, manifest.batch_q, manifest.n_records)?;
        let batch: Vec<[f32; 8]> = queries
            .iter()
            .cycle()
            .take(manifest.batch_q)
            .copied()
            .collect();
        let tb = time_it(8, 64, || {
            std::hint::black_box(batched.query_batch(&batch).unwrap());
        });
        t.row(vec![
            format!("xla batched ({} queries/call, per query)", manifest.batch_q),
            human_ns((tb.p50_ns() / manifest.batch_q as f64) as u64),
            human_ns((tb.p95_ns() / manifest.batch_q as f64) as u64),
            human_ns((tb.mean_ns() / manifest.batch_q as f64) as u64),
        ]);
    } else {
        eprintln!("artifacts missing — run `make artifacts` for the XLA rows");
    }

    t.print();
    t.to_csv(&results_dir().join("perfdb_query.csv"))?;
    println!(
        "\nbuild throughput: {:.1} ms/record ({} sizes each) → projected 100 K records ≈ {:.0} min (paper indexes 100 K in < 20 min)",
        per_record_ms,
        small.fractions.len(),
        projected_100k_min
    );
    Ok(())
}
