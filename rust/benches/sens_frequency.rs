//! §6.3: sensitivity to the tuning frequency (SSSP).
//!
//! Paper: period 0.5 s → up to 25% saving but 17% loss; 5 s → ~2% saving,
//! ~3% loss; 2.5 s is the chosen balance. The tradeoff direction —
//! faster tuning saves more memory but loses more performance — is the
//! shape to reproduce.

use std::path::Path;
use std::sync::Arc;

use tuna::config::experiment::TunaConfig;
use tuna::coordinator::{self, RunSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::report::{pct, results_dir, Table};

fn main() -> tuna::Result<()> {
    let db = Arc::new(ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?);
    let spec = RunSpec::new("SSSP").with_intervals(400);
    let baseline = coordinator::run_fm_only(&spec)?;

    let mut t = Table::new(
        "§6.3 — SSSP sensitivity to tuning period (paper: 0.5s → 25%/17%, 5s → 2%/3%)",
        &["period", "decisions", "mean saving", "max saving", "measured loss"],
    );
    let mut rows = Vec::new();
    for period_s in [0.5, 1.0, 2.5, 5.0] {
        let cfg = TunaConfig { period_s, ..TunaConfig::default() };
        let run = coordinator::run_tuna_native(&spec, db.clone(), &cfg)?;
        let loss = coordinator::overall_loss(&run.result, &baseline);
        t.row(vec![
            format!("{period_s}s"),
            run.decisions.len().to_string(),
            pct(run.mean_saving()),
            pct(run.max_saving()),
            pct(loss),
        ]);
        rows.push((period_s, run.max_saving(), loss));
    }
    t.print();
    t.to_csv(&results_dir().join("sens_frequency.csv"))?;

    let fast = rows.first().unwrap();
    let slow = rows.last().unwrap();
    println!(
        "\nshape check — faster tuning saves more ({} ≥ {}) at more loss ({} ≥ {}): {}",
        pct(fast.1),
        pct(slow.1),
        pct(fast.2),
        pct(slow.2),
        fast.1 >= slow.1 && fast.2 >= slow.2 - 0.005
    );
    Ok(())
}
