//! Tuner-service ingestion throughput: samples/sec through the bounded
//! channel into the background aggregation thread, at 1, 8 and 64
//! concurrent sessions.
//!
//! Each session publishes a fixed number of synthetic samples (period
//! 2.5 s → a decision every 25th sample, so the decision path — NN query
//! + curve scan + mailbox round-trip — is exercised at its realistic
//! duty cycle, not avoided). The aggregation thread is the intended
//! serialization point; this bench measures how much telemetry it
//! absorbs as publishers scale.

use std::sync::Arc;
use std::time::Instant;

use tuna::config::experiment::TunaConfig;
use tuna::perfdb::builder::{build_database, BuildParams};
use tuna::perfdb::native::NativeNn;
use tuna::report::{results_dir, Table};
use tuna::service::{SessionReport, SessionSpec, TunerService};
use tuna::sim::MachineModel;
use tuna::telemetry::TelemetrySample;
use tuna::util::human_ns;

const SAMPLES_PER_SESSION: u32 = 10_000;

fn session_spec(name: String) -> SessionSpec {
    SessionSpec {
        name,
        capacity: 9_000,
        rss_pages: 8_000,
        hot_thr: 2,
        threads: 16,
        cfg: TunaConfig::default(), // 2.5 s period = 25 intervals
    }
}

fn synth_sample(interval: u32, salt: u64) -> TelemetrySample {
    TelemetrySample {
        interval,
        acc_fast: 9_000 + salt % 512,
        acc_slow: 700,
        sacc_fast: 9_000 + salt % 512,
        sacc_slow: 700,
        flops: 500_000,
        iops: 500_000,
        promoted: 25,
        promote_failed: 1,
        demoted_kswapd: 22,
        demoted_direct: 3,
        shadow_hits: salt % 64,
        shadow_free_demotions: 5,
        txn_aborts: 2,
        txn_retried_copies: 1,
        admission_accepted: 20,
        admission_rejected_budget: 2,
        admission_rejected_payoff: 3,
        admission_rejected_cooldown: salt % 8,
        fast_free: 180,
        wall_ns: 1_000_000 + salt % 4_096,
    }
}

fn main() -> tuna::Result<()> {
    let db = Arc::new(build_database(&BuildParams {
        n_configs: 64,
        fractions: vec![1.0, 0.9, 0.8, 0.7, 0.6, 0.5],
        intervals: 3,
        warmup: 1,
        seed: 17,
        machine: MachineModel::default(),
        threads: 4,
    }));

    let mut t = Table::new(
        "telemetry ingestion: samples/sec through the service channel",
        &["sessions", "samples", "decisions", "wall", "samples/sec", "per-sample"],
    );

    for &n_sessions in &[1usize, 8, 64] {
        let service = TunerService::spawn(db.clone(), Box::new(NativeNn::new(&db)));
        let t0 = Instant::now();
        let reports: Vec<SessionReport> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..n_sessions)
                .map(|i| {
                    let service = &service;
                    s.spawn(move || {
                        let mut h = service
                            .register(session_spec(format!("bench-{i}")))
                            .expect("register session");
                        for k in 1..=SAMPLES_PER_SESSION {
                            std::hint::black_box(h.publish(synth_sample(k, i as u64)));
                        }
                        h.finish().expect("session report")
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("publisher thread")).collect()
        });
        let wall_ns = t0.elapsed().as_nanos() as f64;
        service.shutdown();

        let total_samples: u64 = reports.iter().map(|r| r.samples).sum();
        let decisions: usize = reports.iter().map(|r| r.decisions.len()).sum();
        assert_eq!(
            total_samples,
            SAMPLES_PER_SESSION as u64 * n_sessions as u64,
            "every published sample must reach the aggregation thread"
        );
        let rate = total_samples as f64 / (wall_ns / 1e9);
        t.row(vec![
            n_sessions.to_string(),
            total_samples.to_string(),
            decisions.to_string(),
            human_ns(wall_ns as u64),
            format!("{:.0}", rate),
            human_ns((wall_ns / total_samples as f64) as u64),
        ]);
    }

    t.print();
    t.to_csv(&results_dir().join("telemetry_ingest.csv"))?;
    Ok(())
}
