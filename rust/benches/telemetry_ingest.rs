//! Tuner-service ingestion throughput: samples/sec through the bounded
//! channel into the background aggregation workers, at 1, 8 and 64
//! concurrent sessions (thread-per-session) and at fleet scale — 1K and
//! 8K concurrent sessions driven by a publisher pool — across worker
//! counts 1, 2, 4 and 8.
//!
//! Each session publishes a fixed number of synthetic samples (period
//! 2.5 s → a decision every 25th sample, so the decision path — NN query
//! + curve scan + mailbox round-trip — is exercised at its realistic
//! duty cycle, not avoided). The aggregation workers are the intended
//! serialization points; this bench measures how much telemetry they
//! absorb as publishers scale, and how samples/sec scales with the
//! worker count (sessions hash-route to workers, so decisions are
//! bit-identical at any count — only throughput moves).

use std::sync::Arc;
use std::time::Instant;

use tuna::config::experiment::TunaConfig;
use tuna::perfdb::builder::{build_database, BuildParams};
use tuna::perfdb::native::NativeNn;
use tuna::perfdb::PerfDb;
use tuna::report::{results_dir, Table};
use tuna::service::{SessionReport, SessionSpec, TunerService};
use tuna::sim::MachineModel;
use tuna::telemetry::TelemetrySample;
use tuna::util::human_ns;

const SAMPLES_PER_SESSION: u32 = 10_000;

/// Publisher-pool threads for the fleet-scale section (an OS thread per
/// session stops scaling long before 8K sessions do).
const PUBLISHER_POOL: usize = 16;

fn session_spec(name: String) -> SessionSpec {
    SessionSpec {
        name,
        capacity: 9_000,
        rss_pages: 8_000,
        hot_thr: 2,
        threads: 16,
        cfg: TunaConfig::default(), // 2.5 s period = 25 intervals
    }
}

fn synth_sample(interval: u32, salt: u64) -> TelemetrySample {
    TelemetrySample {
        interval,
        acc_fast: 9_000 + salt % 512,
        acc_slow: 700,
        sacc_fast: 9_000 + salt % 512,
        sacc_slow: 700,
        flops: 500_000,
        iops: 500_000,
        promoted: 25,
        promote_failed: 1,
        demoted_kswapd: 22,
        demoted_direct: 3,
        shadow_hits: salt % 64,
        shadow_free_demotions: 5,
        txn_aborts: 2,
        txn_retried_copies: 1,
        admission_accepted: 20,
        admission_rejected_budget: 2,
        admission_rejected_payoff: 3,
        admission_rejected_cooldown: salt % 8,
        fast_free: 180,
        wall_ns: 1_000_000 + salt % 4_096,
    }
}

fn sharded(db: &Arc<PerfDb>, workers: usize) -> TunerService {
    let db2 = db.clone();
    TunerService::spawn_sharded(db.clone(), move |_| Box::new(NativeNn::new(&db2)), workers)
}

/// Thread-per-session: every session gets its own publisher thread, all
/// sessions concurrently open for the whole run.
fn bench_thread_per_session(
    db: &Arc<PerfDb>,
    n_sessions: usize,
    workers: usize,
) -> (Vec<SessionReport>, f64) {
    let service = sharded(db, workers);
    let t0 = Instant::now();
    let reports: Vec<SessionReport> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_sessions)
            .map(|i| {
                let service = &service;
                s.spawn(move || {
                    let mut h = service
                        .register(session_spec(format!("bench-{i}")))
                        .expect("register session");
                    for k in 1..=SAMPLES_PER_SESSION {
                        std::hint::black_box(h.publish(synth_sample(k, i as u64)));
                    }
                    h.finish().expect("session report")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("publisher thread")).collect()
    });
    let wall_ns = t0.elapsed().as_nanos() as f64;
    service.shutdown();
    (reports, wall_ns)
}

/// Fleet scale: a fixed publisher pool drives `n_sessions` concurrently
/// open sessions round-robin — every session is registered up front and
/// samples interleave across the whole fleet, so the per-worker session
/// maps hold their full shard throughout the run.
fn bench_fleet(
    db: &Arc<PerfDb>,
    n_sessions: usize,
    samples_per_session: u32,
    workers: usize,
) -> (Vec<SessionReport>, f64) {
    let service = sharded(db, workers);
    let t0 = Instant::now();
    let reports: Vec<SessionReport> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..PUBLISHER_POOL)
            .map(|p| {
                let service = &service;
                s.spawn(move || {
                    // this publisher's slice of the fleet, all open at once
                    let mut sessions: Vec<_> = (p..n_sessions)
                        .step_by(PUBLISHER_POOL)
                        .map(|i| {
                            let h = service
                                .register(session_spec(format!("fleet-{i}")))
                                .expect("register session");
                            (i as u64, h)
                        })
                        .collect();
                    for k in 1..=samples_per_session {
                        for (salt, h) in sessions.iter_mut() {
                            std::hint::black_box(h.publish(synth_sample(k, *salt)));
                        }
                    }
                    sessions
                        .into_iter()
                        .map(|(_, h)| h.finish().expect("session report"))
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("publisher thread"))
            .collect()
    });
    let wall_ns = t0.elapsed().as_nanos() as f64;
    service.shutdown();
    (reports, wall_ns)
}

fn main() -> tuna::Result<()> {
    let db = Arc::new(build_database(&BuildParams {
        n_configs: 64,
        fractions: vec![1.0, 0.9, 0.8, 0.7, 0.6, 0.5],
        intervals: 3,
        warmup: 1,
        seed: 17,
        machine: MachineModel::default(),
        threads: 4,
    }));

    let mut t = Table::new(
        "telemetry ingestion: samples/sec through the service channel(s)",
        &["sessions", "workers", "samples", "decisions", "wall", "samples/sec", "per-sample"],
    );
    let mut row = |n_sessions: usize,
                   workers: usize,
                   expected_samples: u64,
                   reports: Vec<SessionReport>,
                   wall_ns: f64| {
        let total_samples: u64 = reports.iter().map(|r| r.samples).sum();
        let decisions: usize = reports.iter().map(|r| r.decisions.len()).sum();
        assert_eq!(reports.len(), n_sessions, "every session must report");
        assert_eq!(
            total_samples, expected_samples,
            "every published sample must reach an aggregation worker"
        );
        let rate = total_samples as f64 / (wall_ns / 1e9);
        t.row(vec![
            n_sessions.to_string(),
            workers.to_string(),
            total_samples.to_string(),
            decisions.to_string(),
            human_ns(wall_ns as u64),
            format!("{:.0}", rate),
            human_ns((wall_ns / total_samples as f64) as u64),
        ]);
    };

    // the classic section: thread-per-session, single aggregation worker
    for &n_sessions in &[1usize, 8, 64] {
        let (reports, wall_ns) = bench_thread_per_session(&db, n_sessions, 1);
        row(n_sessions, 1, SAMPLES_PER_SESSION as u64 * n_sessions as u64, reports, wall_ns);
    }

    // fleet scale: 1K and 8K concurrent sessions, worker counts 1..8
    for &(n_sessions, samples_per) in &[(1024usize, 100u32), (8192, 50)] {
        for &workers in &[1usize, 2, 4, 8] {
            let (reports, wall_ns) = bench_fleet(&db, n_sessions, samples_per, workers);
            row(n_sessions, workers, samples_per as u64 * n_sessions as u64, reports, wall_ns);
        }
    }

    drop(row); // release the table borrow
    t.print();
    t.to_csv(&results_dir().join("telemetry_ingest.csv"))?;
    Ok(())
}
