//! Table 2: model prediction error.
//!
//! For each workload: run with fast memory only, profile the telemetry
//! configuration vector, query the performance database for the nearest
//! execution record, and compare the record's predicted relative loss
//! `pd' = (y'-x')/x'` against the measured loss `pd = (y-x)/x` at each
//! fast-memory size. The paper reports `|pd'-pd|/pd` below 10% everywhere,
//! with the error growing as fast memory shrinks (the micro-benchmark's
//! best-case-MLP optimism).

use std::path::Path;

use tuna::coordinator::{self, RunSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::perfdb::native::{NativeNn, NnQuery};
use tuna::perfdb::normalize;
use tuna::report::{results_dir, Table};
use tuna::workloads::ALL_NAMES;

fn main() -> tuna::Result<()> {
    let db = ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?;
    let mut nn = NativeNn::new(&db);
    let fm_sizes = [0.99, 0.98, 0.97, 0.96, 0.95, 0.88, 0.85];

    let mut t = Table::new(
        "Table 2 — model prediction error |pd' - pd| / pd (paper: ≤ 8.1%, growing as FM shrinks)",
        &["Workload", "99%", "98%", "97%", "96%", "95%", "88%", "85%"],
    );
    let mut abs_t = Table::new(
        "Table 2b — absolute error |pd' - pd| (percentage points)",
        &["Workload", "99%", "98%", "97%", "96%", "95%", "88%", "85%"],
    );

    for name in ALL_NAMES {
        let spec = RunSpec::new(name).with_intervals(200);
        // x: fast-memory-only run + telemetry profile
        let (baseline, cfg) = coordinator::profile_tpp(&spec)?;
        let (record, dist) = nn.nearest(&normalize(&cfg.as_array()))?;
        eprintln!("{name}: nearest record {record} (d²={dist:.4})");

        let base_pred = db.time_at(record, 1.0);
        let mut rel_row = vec![name.to_string()];
        let mut abs_row = vec![name.to_string()];
        for &f in &fm_sizes {
            // y: measured at this fast-memory size
            let run = coordinator::run_tpp(&spec.clone().with_fraction(f))?;
            let pd = coordinator::overall_loss(&run, &baseline);
            // y': predicted from the record
            let pd_pred = (db.time_at(record, f) - base_pred) / base_pred;
            let rel = if pd.abs() > 1e-6 { (pd_pred - pd).abs() / pd.abs() } else { f64::NAN };
            rel_row.push(format!("{:.1}%", rel * 100.0));
            abs_row.push(format!("{:.2}pp", (pd_pred - pd).abs() * 100.0));
        }
        t.row(rel_row);
        abs_t.row(abs_row);
    }
    t.print();
    println!();
    abs_t.print();
    t.to_csv(&results_dir().join("table2_accuracy.csv"))?;
    abs_t.to_csv(&results_dir().join("table2_accuracy_abs.csv"))?;
    Ok(())
}
