//! Table 1: workload descriptions + measured per-interval characteristics
//! (RSS, access counts, arithmetic intensity) at our 1 GiB → 4 MiB scale.

use tuna::report::{results_dir, Table};
use tuna::util::human_bytes;
use tuna::workloads::{self, TABLE1};
use tuna::PAGE_BYTES;

fn main() -> tuna::Result<()> {
    let mut t = Table::new(
        "Table 1 — workloads (paper RSS vs instantiated RSS; measured interval profile)",
        &["Workload", "paper RSS", "pages", "bytes", "acc/interval", "AI", "description"],
    );
    for info in TABLE1 {
        let mut w = workloads::by_name(info.name, 42, 12).unwrap();
        let rss = w.rss_pages();
        let _ = w.next_interval(); // allocation epoch
        let mut acc = 0u64;
        let mut ops = 0u64;
        let mut n = 0u64;
        while let Some(p) = w.next_interval() {
            acc += p.total_accesses();
            ops += p.flops + p.iops;
            n += 1;
        }
        let ai = ops as f64 / (acc * tuna::LINE_BYTES) as f64;
        t.row(vec![
            info.name.to_string(),
            format!("{:.1} G", info.paper_rss_gb),
            rss.to_string(),
            human_bytes(rss as u64 * PAGE_BYTES),
            format!("{}", acc / n.max(1)),
            format!("{ai:.3}"),
            info.description.to_string(),
        ]);
    }
    t.print();
    t.to_csv(&results_dir().join("table1_workloads.csv"))?;
    Ok(())
}
