//! Figure 1: BFS performance with various fast-memory sizes, with and
//! without a page-management system (TPP vs NUMA first-touch).
//!
//! Paper anchors: at 89.5% fast memory, first-touch loses 8.8% and TPP
//! 4.4%; at 26.6% even TPP loses 30.2%, with +21% migration failures and
//! +40% migrations vs 89.5%. We reproduce the *shape*: TPP strictly above
//! first-touch at moderate shrink, both collapsing at deep shrink, and
//! both failure and migration counts rising from 89.5% → 26.6%.
//!
//! Runs through the batched sweep executor: the full 8-fraction ×
//! 2-policy grid executes across threads against one memoized
//! fast-memory-only baseline (16 cells, 1 baseline run). The baseline is
//! served from the artifact store when a previous invocation persisted
//! it (rerun this bench: "0 computed, 1 loaded from disk"), and the cell
//! table lands in the store for `tuna store diff` across commits.

use std::path::Path;

use tuna::artifact::cells::SweepTable;
use tuna::artifact::ArtifactStore;
use tuna::coordinator::{run_sweep_with_cache, BaselineCache, SweepPolicy, SweepSpec};
use tuna::report::{pct, results_dir, Table};
use tuna::util::human_ns;

fn main() -> tuna::Result<()> {
    let fractions = [1.0, 0.95, 0.895, 0.8, 0.7, 0.5, 0.3, 0.266];
    let spec = SweepSpec::new(["BFS"])
        .with_fractions(fractions)
        .with_policies([SweepPolicy::Tpp, SweepPolicy::FirstTouch])
        .with_intervals(240);
    let store = ArtifactStore::open(Path::new("artifacts/store"))?;
    let cache = BaselineCache::persistent(&store.baselines_dir())?;
    let res = run_sweep_with_cache(&spec, &cache)?;

    let mut t = Table::new(
        "Fig. 1 — BFS vs fast-memory size (normalized performance; paper: TPP 0.956 @ 89.5%, first-touch 0.919 @ 89.5%, TPP 0.77 @ 26.6%)",
        &["FM size", "TPP perf", "TPP loss", "first-touch perf", "first-touch loss", "TPP migrations", "TPP failures"],
    );
    let mut anchors = Vec::new();
    for &f in &fractions {
        let tpp = res.cell("BFS", SweepPolicy::Tpp, f).expect("tpp cell");
        let ft = res.cell("BFS", SweepPolicy::FirstTouch, f).expect("first-touch cell");
        t.row(vec![
            pct(f),
            format!("{:.3}", 1.0 / (1.0 + tpp.loss)),
            pct(tpp.loss),
            format!("{:.3}", 1.0 / (1.0 + ft.loss)),
            pct(ft.loss),
            tpp.result.total_migrations().to_string(),
            tpp.result.total_promote_failed().to_string(),
        ]);
        anchors.push((
            f,
            tpp.loss,
            ft.loss,
            tpp.result.total_migrations(),
            tpp.result.total_promote_failed(),
        ));
    }
    t.print();
    t.to_csv(&results_dir().join("fig1_motivation.csv"))?;
    let cells_path = store.sweep_path("fig1_motivation");
    SweepTable::from_sweep(&res).save(&cells_path)?;
    println!(
        "\nsweep executor: {} cells in {} (baselines: {} computed, {} cache hits, {} loaded from disk)",
        res.len(),
        human_ns(res.wall_ns as u64),
        res.baselines_computed,
        res.baseline_hits,
        res.baseline_disk_hits
    );
    println!("cells persisted to {} (diff across commits with `tuna store diff`)", cells_path.display());

    // Shape checks the paper's narrative rests on.
    let at = |f: f64| anchors.iter().find(|a| (a.0 - f).abs() < 1e-9).unwrap();
    let a895 = at(0.895);
    let a266 = at(0.266);
    println!("\nshape checks:");
    println!(
        "  first-touch worse than TPP at 89.5%: {} ({} vs {})",
        a895.2 > a895.1,
        pct(a895.2),
        pct(a895.1)
    );
    println!(
        "  TPP loss grows 89.5% -> 26.6%:       {} ({} -> {})",
        a266.1 > a895.1,
        pct(a895.1),
        pct(a266.1)
    );
    println!(
        "  migrations up (paper +40%):          {} (+{:.0}%)",
        a266.3 > a895.3,
        100.0 * (a266.3 as f64 / a895.3 as f64 - 1.0)
    );
    println!(
        "  failures up (paper +21%):            {} (+{:.0}%)",
        a266.4 > a895.4,
        100.0 * (a266.4 as f64 / a895.4.max(1) as f64 - 1.0)
    );
    Ok(())
}
