//! Figure 8: TPP with and without Tuna for BFS — page migrations and
//! fast-memory saving over time. The paper's point: Tuna's watermark
//! changes perturb TPP's migration activity, and those bursts are what
//! buy the fast-memory saving without a large performance loss.

use std::path::Path;
use std::sync::Arc;

use tuna::config::experiment::TunaConfig;
use tuna::coordinator::{self, RunSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::report::{ascii_series, pct, results_dir, Table};
use tuna::workloads;

fn main() -> tuna::Result<()> {
    let db = Arc::new(ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?);
    let tuna_cfg = TunaConfig::default();
    let spec = RunSpec::new("BFS").with_intervals(500);
    let rss = workloads::by_name("BFS", spec.seed, 1).unwrap().rss_pages() as u64;

    let plain = coordinator::run_tpp(&spec)?; // TPP at 100% (no Tuna)
    let tuned = coordinator::run_tuna_native(&spec, db, &tuna_cfg)?;

    let period = tuna_cfg.period_intervals() as usize;
    let bucket = |run: &tuna::sim::RunResult, f: &dyn Fn(&tuna::sim::RunTrace) -> u64| -> Vec<f64> {
        run.trace
            .chunks(period)
            .map(|c| c.iter().map(|t| f(t) as f64).sum::<f64>())
            .collect()
    };
    let mig_plain = bucket(&plain, &|t| t.promoted + t.demoted_kswapd + t.demoted_direct);
    let mig_tuned = bucket(&tuned.result, &|t| t.promoted + t.demoted_kswapd + t.demoted_direct);
    let xs: Vec<f64> = (0..mig_tuned.len()).map(|i| i as f64 * 2.5).collect();

    println!("{}", ascii_series("Fig. 8a — migrations/period, TPP+Tuna", &xs, &mig_tuned, 6));
    let xs_p: Vec<f64> = (0..mig_plain.len()).map(|i| i as f64 * 2.5).collect();
    println!("{}", ascii_series("Fig. 8a' — migrations/period, TPP alone", &xs_p, &mig_plain, 6));

    let fm = coordinator::fm_fraction_series(&tuned.result, rss);
    let fx: Vec<f64> = (0..fm.len()).map(|i| i as f64 * 0.1).collect();
    println!("{}", ascii_series("Fig. 8b — usable FM fraction, TPP+Tuna", &fx, &fm, 6));

    let mut t = Table::new(
        "Fig. 8 — TPP vs TPP+Tuna (BFS)",
        &["config", "migrations", "promote failures", "mean FM saving", "max FM saving"],
    );
    t.row(vec![
        "TPP".into(),
        plain.total_migrations().to_string(),
        plain.total_promote_failed().to_string(),
        pct(0.0),
        pct(0.0),
    ]);
    t.row(vec![
        "TPP+Tuna".into(),
        tuned.result.total_migrations().to_string(),
        tuned.result.total_promote_failed().to_string(),
        pct(tuned.mean_saving()),
        pct(tuned.max_saving()),
    ]);
    t.print();
    t.to_csv(&results_dir().join("fig8_migrations.csv"))?;

    println!(
        "\nshape check — Tuna induces migration activity TPP alone lacks: {}",
        tuned.result.total_migrations() > plain.total_migrations()
    );
    Ok(())
}
