//! Table 3: sensitivity to the performance-loss target (SSSP).
//!
//! Paper: τ = 5% → 9% saving / 4.6% loss; τ = 10% → 18% / 9.6%;
//! τ = 15% → 27% / 15.1% (slight target violation at 15%, blamed on the
//! model's growing prediction error at small FM sizes — Table 2).

use std::path::Path;
use std::sync::Arc;

use tuna::config::experiment::TunaConfig;
use tuna::coordinator::{self, RunSpec};
use tuna::perfdb::builder::{ensure_db, BuildParams};
use tuna::report::{pct, results_dir, Table};

fn main() -> tuna::Result<()> {
    let db = Arc::new(ensure_db(Path::new("artifacts/perfdb.bin"), &BuildParams::default())?);
    let spec = RunSpec::new("SSSP").with_intervals(400);
    let baseline = coordinator::run_fm_only(&spec)?;

    let mut t = Table::new(
        "Table 3 — SSSP sensitivity to loss target (paper: 9%/4.6%, 18%/9.6%, 27%/15.1%)",
        &["target", "mean FM saving", "max FM saving", "measured loss", "within target"],
    );
    let mut prev_saving = -1.0f64;
    for target in [0.05, 0.10, 0.15] {
        let cfg = TunaConfig { loss_target: target, ..TunaConfig::default() };
        let run = coordinator::run_tuna_native(&spec, db.clone(), &cfg)?;
        let loss = coordinator::overall_loss(&run.result, &baseline);
        t.row(vec![
            pct(target),
            pct(run.mean_saving()),
            pct(run.max_saving()),
            pct(loss),
            // the paper tolerates a slight violation at 15%
            format!("{}", loss <= target * 1.25),
        ]);
        if run.mean_saving() + 0.02 < prev_saving {
            eprintln!(
                "note: saving dipped {} -> {} (sawtooth grow-backs add noise)",
                prev_saving,
                run.mean_saving()
            );
        }
        prev_saving = run.mean_saving();
    }
    t.print();
    t.to_csv(&results_dir().join("table3_loss_target.csv"))?;
    Ok(())
}
