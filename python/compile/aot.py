"""AOT pipeline: lower the L2 query model (wrapping the L1 Pallas kernel)
to HLO **text** and write the artifacts the rust runtime loads via PJRT.

HLO text — NOT `lowered.compile()` / proto `.serialize()` — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the image's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`);
the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md).

Artifacts (under --out-dir, default ../artifacts):
  perfdb_query_n{N}.hlo.txt       1-query executable  (the tuner's path)
  perfdb_query_q{Q}_n{N}.hlo.txt  batched executable  (throughput bench)
  manifest.txt                    key=value index the rust side parses

Python runs ONCE, at build time (`make artifacts`); it is never on the
request path.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.distance import BLOCK_N, DIMS
from .model import perfdb_query, perfdb_query_topk

# Database slots in the default artifact. The rust runtime pads its record
# matrix up to this count (PAD_VALUE rows never win the argmin); builds
# needing more records regenerate with --n-records.
DEFAULT_N = 4096
BATCH_Q = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_query(n_q: int, n_records: int) -> str:
    q_spec = jax.ShapeDtypeStruct((n_q, DIMS), jnp.float32)
    db_spec = jax.ShapeDtypeStruct((n_records, DIMS), jnp.float32)
    lowered = jax.jit(perfdb_query).lower(q_spec, db_spec)
    return to_hlo_text(lowered)


# Top-k variant: the tuner averages the k nearest records' loss curves
# (distance-weighted), which smooths the step-function character of
# individual micro-benchmark records.
TOP_K = 8


def lower_query_topk(n_q: int, n_records: int, k: int = TOP_K) -> str:
    q_spec = jax.ShapeDtypeStruct((n_q, DIMS), jnp.float32)
    db_spec = jax.ShapeDtypeStruct((n_records, DIMS), jnp.float32)
    lowered = jax.jit(lambda q, db: perfdb_query_topk(q, db, k=k)).lower(q_spec, db_spec)
    return to_hlo_text(lowered)


def build(out_dir: str, n_records: int, batch_q: int) -> dict:
    assert n_records % min(BLOCK_N, n_records) == 0
    os.makedirs(out_dir, exist_ok=True)
    files = {}

    single = f"perfdb_query_n{n_records}.hlo.txt"
    with open(os.path.join(out_dir, single), "w") as f:
        f.write(lower_query(1, n_records))
    files["single"] = single

    batched = f"perfdb_query_q{batch_q}_n{n_records}.hlo.txt"
    with open(os.path.join(out_dir, batched), "w") as f:
        f.write(lower_query(batch_q, n_records))
    files["batched"] = batched

    topk = f"perfdb_query_top{TOP_K}_n{n_records}.hlo.txt"
    with open(os.path.join(out_dir, topk), "w") as f:
        f.write(lower_query_topk(1, n_records))
    files["topk"] = topk

    manifest = os.path.join(out_dir, "manifest.txt")
    with open(manifest, "w") as f:
        f.write("# Tuna AOT artifact manifest (parsed by rust/src/runtime)\n")
        f.write("[artifacts]\n")
        f.write(f'single = "{single}"\n')
        f.write(f'batched = "{batched}"\n')
        f.write(f'topk = "{topk}"\n')
        f.write(f"top_k = {TOP_K}\n")
        f.write(f"n_records = {n_records}\n")
        f.write(f"batch_q = {batch_q}\n")
        f.write(f"dims = {DIMS}\n")
        f.write(f"block_n = {min(BLOCK_N, n_records)}\n")
    files["manifest"] = manifest
    return files


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n-records", type=int, default=DEFAULT_N)
    ap.add_argument("--batch-q", type=int, default=BATCH_Q)
    args = ap.parse_args()
    files = build(args.out_dir, args.n_records, args.batch_q)
    for k, v in files.items():
        print(f"wrote {k}: {v}")


if __name__ == "__main__":
    main()
