"""L2 JAX model: the performance-database nearest-neighbour query.

The pipeline the rust coordinator executes via PJRT every tuning period:

    perfdb_query(q, db) -> (idx, dist)

where `q` is the (already-normalized — normalization is defined once, in
rust `perfdb::normalize`) telemetry configuration vector(s) and `db` the
normalized record matrix (padded to a multiple of the kernel block). The
distance computation is the L1 Pallas kernel; argmin + gather fuse around
it in one HLO module, so a query is a single executable invocation with a
scalar-sized result (no O(N) transfer back to the host).
"""

import jax
import jax.numpy as jnp

from .kernels.distance import pairwise_sq_dists

# Sentinel coordinate for padding rows (real normalized coords are ~[0,1.3],
# so padded rows sit at distance >= (100-1.3)^2 * 8 from any query).
PAD_VALUE = 100.0


def perfdb_query(q, db):
    """Nearest database record per query.

    q:  (Q, 8) f32 normalized query vectors
    db: (N, 8) f32 normalized record vectors, N % BLOCK_N == 0
    returns (idx (Q,) i32, dist (Q,) f32)
    """
    dists = pairwise_sq_dists(q, db)
    idx = jnp.argmin(dists, axis=1).astype(jnp.int32)
    dist = jnp.take_along_axis(dists, idx[:, None], axis=1)[:, 0]
    return idx, dist


def perfdb_query_topk(q, db, k=4):
    """Top-k variant (the tuner's k-NN curve averaging).

    Implemented as k argmin+mask passes rather than `lax.top_k`: the
    image's XLA 0.5.1 HLO-text parser predates the dedicated `topk` op
    (its `largest=` attribute fails to parse), while argmin + scatter
    round-trip cleanly. k is tiny (≤ 8), so the extra passes are noise.

    returns (idx (Q, k) i32, dist (Q, k) f32), ascending by distance.
    """
    dists = pairwise_sq_dists(q, db)
    n_q = dists.shape[0]
    rows = jnp.arange(n_q)
    idxs, vals = [], []
    d = dists
    for _ in range(k):
        i = jnp.argmin(d, axis=1)
        v = jnp.take_along_axis(d, i[:, None], axis=1)[:, 0]
        idxs.append(i.astype(jnp.int32))
        vals.append(v)
        d = d.at[rows, i].set(jnp.float32(3.4e38))
    return jnp.stack(idxs, axis=1), jnp.stack(vals, axis=1)


def pad_db(db, block_n):
    """Pad the record matrix to a multiple of `block_n` with PAD_VALUE."""
    n = db.shape[0]
    padded_n = ((n + block_n - 1) // block_n) * block_n
    if padded_n == n:
        return db
    pad = jnp.full((padded_n - n, db.shape[1]), PAD_VALUE, db.dtype)
    return jnp.concatenate([db, pad], axis=0)
