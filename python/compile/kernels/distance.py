"""L1 Pallas kernel: blocked squared-L2 distance between query vectors and
the performance-database matrix.

This is Tuna's compute hot-spot (the Faiss-index stand-in, DESIGN.md §2):
every tuning period the runtime searches the database of micro-benchmark
execution records for the nearest configuration vector. The kernel computes

    dist[q, n] = ||Q[q] - D[n]||^2 = ||Q[q]||^2 - 2 Q[q]·D[n] + ||D[n]||^2

with the cross term as one matmul per database block — the MXU-friendly
formulation (a (BLOCK_N, DIMS) x (DIMS, Q) systolic matmul per grid step)
rather than an elementwise diff-square-reduce, which would waste the MXU
and triple VMEM traffic.

TPU mapping (DESIGN.md §3):
  * grid over N: each step streams one (BLOCK_N, DIMS) database tile
    HBM -> VMEM via its BlockSpec (the analogue of Faiss scanning one
    inverted list);
  * the query tile (Q, DIMS) is broadcast to every step (index_map pins
    it to block (0, 0));
  * f32 accumulate; BLOCK_N = 1024 keeps the working set
    (1024x8 + 8xQ + 1024xQ floats ~ 40 KiB at Q=1) far under VMEM.

`interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU performance is *estimated* from the block shapes in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Database tile size along N. See the VMEM budget note above.
BLOCK_N = 1024

# Configuration-vector dimensionality (must match rust perfdb::DIMS).
DIMS = 8


def _distance_kernel(q_ref, db_ref, out_ref):
    """One grid step: distances from all queries to one database block.

    q_ref:   (Q, DIMS)        broadcast query tile
    db_ref:  (BLOCK_N, DIMS)  database tile for this step
    out_ref: (Q, BLOCK_N)     output tile
    """
    q = q_ref[...]
    db = db_ref[...]
    # ||d||^2 per database row: (BLOCK_N,)
    d_sq = jnp.sum(db * db, axis=1)
    # ||q||^2 per query row: (Q, 1)
    q_sq = jnp.sum(q * q, axis=1, keepdims=True)
    # cross term on the MXU: (Q, BLOCK_N)
    cross = jnp.dot(q, db.T, preferred_element_type=jnp.float32)
    out_ref[...] = q_sq - 2.0 * cross + d_sq[None, :]


@functools.partial(jax.jit, static_argnames=("block_n",))
def pairwise_sq_dists(q, db, block_n=BLOCK_N):
    """Squared L2 distances, (Q, N), via the blocked Pallas kernel.

    Requires N % block_n == 0 (the AOT pipeline pads the database; the
    padding rows use a large sentinel so they never win the argmin).
    """
    q = jnp.asarray(q, jnp.float32)
    db = jnp.asarray(db, jnp.float32)
    n_q, dims = q.shape
    n, dims2 = db.shape
    assert dims == DIMS and dims2 == DIMS, f"expected {DIMS}-dim vectors"
    block_n = min(block_n, n)
    assert n % block_n == 0, f"N={n} not a multiple of block_n={block_n}"

    grid = (n // block_n,)
    return pl.pallas_call(
        _distance_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n_q, DIMS), lambda i: (0, 0)),
            pl.BlockSpec((block_n, DIMS), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n_q, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n_q, n), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(q, db)


def vmem_bytes(block_n=BLOCK_N, n_q=1):
    """Estimated VMEM working set of one grid step (perf accounting)."""
    f32 = 4
    db_tile = block_n * DIMS * f32
    q_tile = n_q * DIMS * f32
    out_tile = n_q * block_n * f32
    return db_tile + q_tile + out_tile
