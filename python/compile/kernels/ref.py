"""Pure-jnp oracle for the distance kernel and the query pipeline.

The CORE correctness signal: pytest asserts the Pallas kernel (and the
whole lowered query model) match these references to float tolerance.
"""

import jax.numpy as jnp


def pairwise_sq_dists_ref(q, db):
    """Naive O(Q*N*D) squared L2 distances, (Q, N)."""
    q = jnp.asarray(q, jnp.float32)
    db = jnp.asarray(db, jnp.float32)
    diff = q[:, None, :] - db[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def nearest_ref(q, db):
    """(indices (Q,), distances (Q,)) of each query's nearest db row."""
    d = pairwise_sq_dists_ref(q, db)
    idx = jnp.argmin(d, axis=1)
    return idx.astype(jnp.int32), jnp.take_along_axis(d, idx[:, None], axis=1)[:, 0]
