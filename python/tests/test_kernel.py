"""L1 kernel vs pure-jnp oracle — the CORE correctness signal.

hypothesis sweeps shapes and value ranges; fixed cases cover the edges
(single block, multi-block, duplicate rows, extreme values).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.distance import BLOCK_N, DIMS, pairwise_sq_dists, vmem_bytes
from compile.kernels.ref import pairwise_sq_dists_ref


def rand(shape, seed, lo=-2.0, hi=2.0):
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


@pytest.mark.parametrize("n", [8, 64, 1024, 4096])
@pytest.mark.parametrize("n_q", [1, 4])
def test_kernel_matches_ref(n, n_q):
    q = rand((n_q, DIMS), seed=n + n_q)
    db = rand((n, DIMS), seed=n * 7 + n_q)
    got = pairwise_sq_dists(q, db)
    want = pairwise_sq_dists_ref(q, db)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_multi_block_grid_covers_every_block():
    # 4 blocks; plant a distinctive row in each block and check its
    # distance is exact (catches index_map bugs).
    n = 4 * BLOCK_N
    db = np.zeros((n, DIMS), dtype=np.float32)
    for b in range(4):
        db[b * BLOCK_N + 17, :] = b + 1.0
    q = np.zeros((1, DIMS), dtype=np.float32)
    d = np.asarray(pairwise_sq_dists(q, db))
    for b in range(4):
        np.testing.assert_allclose(d[0, b * BLOCK_N + 17], DIMS * (b + 1.0) ** 2, rtol=1e-6)


def test_identical_rows_have_zero_distance():
    db = rand((128, DIMS), seed=3)
    q = db[42:43]
    d = np.asarray(pairwise_sq_dists(q, db))
    assert d[0, 42] == pytest.approx(0.0, abs=1e-5)
    assert (d >= -1e-4).all(), "squared distances must be non-negative"


@settings(max_examples=30, deadline=None)
@given(
    n_blocks=st.integers(min_value=1, max_value=4),
    n_q=st.integers(min_value=1, max_value=8),
    scale=st.floats(min_value=0.01, max_value=50.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(n_blocks, n_q, scale, seed):
    # small block so hypothesis can sweep multi-block grids cheaply
    block = 64
    n = n_blocks * block
    q = rand((n_q, DIMS), seed=seed, lo=-scale, hi=scale)
    db = rand((n, DIMS), seed=seed + 1, lo=-scale, hi=scale)
    got = np.asarray(pairwise_sq_dists(q, db, block_n=block))
    want = np.asarray(pairwise_sq_dists_ref(q, db))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4 * scale * scale)


def test_non_multiple_n_is_rejected():
    q = rand((1, DIMS), seed=1)
    db = rand((BLOCK_N + 5, DIMS), seed=2)
    with pytest.raises(AssertionError):
        pairwise_sq_dists(q, db)


def test_wrong_dims_rejected():
    q = rand((1, DIMS + 1), seed=1)
    db = rand((64, DIMS + 1), seed=2)
    with pytest.raises(AssertionError):
        pairwise_sq_dists(q, db)


def test_vmem_budget_within_tpu_limits():
    # one grid step must fit comfortably in a 16 MiB VMEM (we budget 2 MiB)
    assert vmem_bytes(BLOCK_N, n_q=1) < 2 * 1024 * 1024
    assert vmem_bytes(BLOCK_N, n_q=64) < 2 * 1024 * 1024
