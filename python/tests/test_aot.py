"""AOT pipeline tests: lowering produces loadable HLO text + manifest."""

import os

import pytest

from compile.aot import build, lower_query
from compile.kernels.distance import DIMS


def test_lowered_hlo_is_text_with_entry(tmp_path):
    text = lower_query(1, 256)
    assert "HloModule" in text
    assert "ENTRY" in text
    # the blocked matmul (dot) from the Pallas kernel must be in there
    assert "dot(" in text or "dot " in text


def test_build_writes_artifacts_and_manifest(tmp_path):
    files = build(str(tmp_path), n_records=256, batch_q=8)
    single = tmp_path / files["single"]
    batched = tmp_path / files["batched"]
    manifest = tmp_path / "manifest.txt"
    assert single.exists() and single.stat().st_size > 1000
    assert batched.exists() and batched.stat().st_size > 1000
    assert manifest.exists()
    text = manifest.read_text()
    assert "n_records = 256" in text
    assert f"dims = {DIMS}" in text
    assert files["single"] in text


def test_lowering_is_shape_specific(tmp_path):
    a = lower_query(1, 256)
    b = lower_query(1, 512)
    assert a != b
    # shapes are baked in
    assert "256" in a and "512" in b
