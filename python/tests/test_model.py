"""L2 query-model tests: argmin/gather correctness, padding semantics,
top-k ablation path."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.distance import DIMS
from compile.kernels.ref import nearest_ref
from compile.model import PAD_VALUE, pad_db, perfdb_query, perfdb_query_topk


def rand(shape, seed, lo=0.0, hi=1.3):
    # normalized config vectors live in ~[0, 1.3] (rust perfdb::normalize)
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, size=shape).astype(np.float32)


@pytest.mark.parametrize("n", [64, 256, 1024])
def test_query_matches_ref(n):
    q = rand((4, DIMS), seed=n)
    db = rand((n, DIMS), seed=n + 1)
    idx, dist = perfdb_query(q, db)
    ridx, rdist = nearest_ref(q, db)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rdist), rtol=1e-5, atol=1e-5)


def test_exact_match_wins():
    db = rand((512, DIMS), seed=9)
    q = db[137:138]
    idx, dist = perfdb_query(q, db)
    assert int(idx[0]) == 137
    assert float(dist[0]) == pytest.approx(0.0, abs=1e-5)


def test_padding_rows_never_win():
    db = rand((100, DIMS), seed=5)
    padded = pad_db(jnp.asarray(db), 64)  # pads 100 -> 128
    assert padded.shape[0] == 128
    assert float(padded[100, 0]) == PAD_VALUE
    q = rand((8, DIMS), seed=6)
    idx, _ = perfdb_query(q, padded)
    assert (np.asarray(idx) < 100).all(), "a padding row won the argmin"


def test_pad_db_noop_when_aligned():
    db = jnp.asarray(rand((128, DIMS), seed=1))
    assert pad_db(db, 64) is db


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([64, 128, 192]),
    n_q=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_query_matches_ref_hypothesis(n, n_q, seed):
    q = rand((n_q, DIMS), seed=seed)
    db = rand((n, DIMS), seed=seed + 1)
    idx, dist = perfdb_query(q, db)
    ridx, rdist = nearest_ref(q, db)
    # ties can differ in index; distances must match exactly-ish
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rdist), rtol=1e-4, atol=1e-5)
    d = np.asarray(
        ((np.asarray(q)[:, None, :] - np.asarray(db)[None, :, :]) ** 2).sum(-1)
    )
    got = d[np.arange(n_q), np.asarray(idx)]
    np.testing.assert_allclose(got, np.asarray(rdist), rtol=1e-4, atol=1e-5)


def test_topk_is_sorted_and_contains_nearest():
    db = rand((256, DIMS), seed=2)
    q = rand((3, DIMS), seed=3)
    idx, dist = perfdb_query_topk(q, db, k=5)
    assert idx.shape == (3, 5)
    d = np.asarray(dist)
    assert (np.diff(d, axis=1) >= -1e-6).all(), "top-k distances must ascend"
    nidx, _ = perfdb_query(q, db)
    assert (np.asarray(idx)[:, 0] == np.asarray(nidx)).all()
